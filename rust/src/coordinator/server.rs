//! The serving loop: continuous-batched greedy decoding through a token
//! engine, with per-token RACAM latency accounting from the shared mapping
//! service (the simulated-hardware clock) next to the host wall clock.
//!
//! A [`Server`] is one worker shard: it owns a token engine, a
//! [`RacamSystem`] handle (typically sharing its [`MappingService`] with
//! every other shard — see [`super::Coordinator`]), a pluggable admission
//! [`Scheduler`] (FCFS by default), a [`ServingPolicy`] governing the
//! iteration engine, and persistent per-bucket prefill and decode cost
//! caches so repeated runs never re-price a bucket.  Pricing a bucket
//! runs the kernel shapes through the mapping service's cached
//! best-first search — when the service has a warm store attached
//! ([`ClusterSpec::mapping_store`](crate::config::ClusterSpec), see
//! `docs/mapping.md`), a context-bucket crossing whose shapes were
//! searched by *any* earlier run answers from the loaded table instead
//! of searching.
//!
//! ## The serving engines
//!
//! `run_to_completion` drives a sequence of simulated *steps*.  Each step
//! is one of:
//!
//! * **prefill step** — charge a bounded chunk of one staged prompt
//!   ([`ServingPolicy::prefill_chunk_tokens`]; unset = the whole prompt,
//!   the paper-faithful legacy schedule, reproduced bit-for-bit);
//! * **decode iteration** — one lockstep decode step across every batch
//!   member whose prompt is fully prefilled, charging the slowest member's
//!   per-token cost;
//! * **preemption scan** — when the policy enables it, the scheduler's
//!   [`Scheduler::should_preempt`] hook may shed or re-queue running
//!   requests (EDF sheds past-deadline work);
//! * **idle jump / intake block** — the clock jumps to the next future
//!   arrival (accounted as [`ShardStats::sim_idle_ns`]) or the loop blocks
//!   on the live intake channel.
//!
//! Two implementations run that schedule
//! ([`ServingPolicy::engine`](crate::config::EngineKind)):
//!
//! * the **event-calendar engine** (default) — when the batch is in a
//!   uniform lockstep-decode stretch (every member decoding, no admission
//!   possible before a membership change), it fast-forwards to the next
//!   material event — arrival release, token-budget completion, pricing-
//!   bucket edge, preemption horizon — instead of paying the full
//!   scheduling round per token; prefill selection pops an SRPT-keyed
//!   index, and per-member decode pricing is a precomputed bucket
//!   schedule.  See `docs/serving.md` ("Engine internals").
//! * the **per-iteration oracle** — the reference loop that runs the
//!   complete round every simulated step.  Simulated results (timestamps,
//!   costs, tokens, stats) are bit-identical between the two, which the
//!   equivalence suite in `tests/engine_equivalence.rs` pins.
//!
//! With chunking enabled, a long prompt no longer stalls every running
//! decode: prefill advances one chunk per iteration and decode iterations
//! interleave between chunks.  The time decoders spend waiting on prefill
//! steps is surfaced as [`ShardStats::chunk_stall_ns`].
//!
//! ## The simulated clock and open-loop traffic
//!
//! Requests carry an [`Request::arrival_ns`] on the shard clock — a
//! request is invisible to the [`Scheduler`] until the clock reaches its
//! arrival, which is how the open-loop streams of [`crate::traffic`]
//! replay: queueing delay emerges from load instead of being assumed.
//! When the shard is idle and work is pending in the future, the clock
//! jumps to the next arrival and the gap is accounted as idle time.
//!
//! ## Async admission
//!
//! [`Server::open_intake`] (and [`super::Coordinator::intake`]) return an
//! mpsc sender; requests sent on it are admitted *mid-run*: the serving
//! loop drains the channel between iterations, and blocks on it when it
//! would otherwise go idle.  `run_to_completion` returns once all queued
//! work is done **and** every intake sender has been dropped.
//!
//! ## Roles (prefill/decode disaggregation)
//!
//! A shard carries a [`ShardRole`].  `Unified` (the default) serves the
//! whole lifecycle.  On a `Prefill` shard, a prompt that finishes prefill
//! leaves as a [`Handoff`] (no decode, no result emitted here) for the
//! coordinator to ship to a decode shard.  A `Decode` shard receives
//! handoffs via [`Server::submit_handoff`]: the request is released once
//! the simulated clock reaches *prefill finish + KV transfer*, admission
//! skips prefill, the prefill shard's intrinsic cost and the original
//! arrival carry over into the result, and the transfer time lands in
//! [`ShardStats::kv_transfer_ns`].
//!
//! [`MappingService`]: crate::mapping::MappingService

use super::batcher::{ctx_bucket, FcfsBatcher};
use super::engine::TokenEngine;
use super::scheduler::{Preemption, Scheduler};
use crate::config::{EngineKind, LlmSpec, ServingPolicy, ShardRole};
use crate::metrics::LatencyBreakdown;
use crate::telemetry::{Event, EventKind, NopRecorder, Recorder, NO_REQ};
use crate::workloads::{decode_kernels, prefill_kernels, stage_latency, RacamSystem};
use crate::Result;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc;
use std::time::Instant;

/// An inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival time on the shard's simulated clock, ns.  Zero (the
    /// default) means "present before the run starts"; a positive value
    /// hides the request from the scheduler until the clock reaches it.
    pub arrival_ns: u64,
    /// Optional end-to-end completion deadline on the simulated clock, ns
    /// (absolute, not relative to arrival).  Consumed by deadline-aware
    /// schedulers and the SLO goodput accounting in [`crate::traffic::slo`].
    pub deadline_ns: Option<u64>,
}

impl Request {
    /// A request available at clock start with no deadline.
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request { id, prompt, max_new_tokens, arrival_ns: 0, deadline_ns: None }
    }

    /// Set the simulated arrival time (open-loop traffic).
    pub fn at(mut self, arrival_ns: u64) -> Self {
        self.arrival_ns = arrival_ns;
        self
    }

    /// Set an absolute completion deadline on the simulated clock.
    pub fn with_deadline(mut self, deadline_ns: u64) -> Self {
        self.deadline_ns = Some(deadline_ns);
        self
    }
}

/// Completed request with its generation and accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Prompt length of the request, tokens (lets SLO analyses split
    /// populations by prompt length — e.g. short-request TTFT under a
    /// long-prompt mixed workload — without a lookup back to the stream).
    pub prompt_tokens: usize,
    /// Simulated RACAM time to first token (prefill cost alone, excluding
    /// queueing), ns.
    pub sim_ttft_ns: f64,
    /// Simulated RACAM service time attributed to this request (prefill +
    /// its own per-token decode costs), ns.
    pub sim_total_ns: f64,
    /// Host wall-clock attributed to this request, ns: the shard's run
    /// wall time apportioned by the request's share of simulated service
    /// time.  Wall time is measured once per run (a single timer around
    /// the serving loop — never inside the hot path), so this is a
    /// reporting convenience, not a per-request measurement; host-speed
    /// analyses should use [`ShardStats::wall_ns`].
    pub wall_ns: f64,
    /// Arrival time on the shard's simulated clock, ns.
    pub arrival_ns: f64,
    /// Absolute simulated-clock time the first token was ready (includes
    /// queueing delay; `- arrival_ns` is the serving-level TTFT).  For a
    /// request shed before its first token, this is not meaningful —
    /// latency populations should exclude shed requests.
    pub sim_first_token_at_ns: f64,
    /// Absolute simulated-clock completion (or shed) time.
    pub sim_finish_at_ns: f64,
    /// Echo of the request's deadline, for goodput accounting.
    pub deadline_ns: Option<f64>,
    /// True when the request was preemptively shed ([`Preemption::Shed`])
    /// instead of running to completion: `tokens` holds whatever was
    /// generated before the shed, and the request counts as missing its
    /// deadline.
    pub shed: bool,
    /// True when the request terminated as *failed*: it was evacuated
    /// from a crashed shard and never completed — its retry budget ran
    /// out, or no eligible shard survived (see `docs/robustness.md`).
    /// Distinct from `shed` (a scheduling decision): `tokens` is empty
    /// and the request counts as missing its deadline.
    pub failed: bool,
}

impl RequestResult {
    /// Serving-level time-to-first-token: queueing delay + prefill.
    pub fn ttft_ns(&self) -> f64 {
        self.sim_first_token_at_ns - self.arrival_ns
    }

    /// Serving-level end-to-end latency (arrival to completion).
    pub fn e2e_ns(&self) -> f64 {
        self.sim_finish_at_ns - self.arrival_ns
    }

    /// Mean time per output token after the first.
    pub fn tpot_ns(&self) -> f64 {
        if self.tokens.len() < 2 {
            return 0.0;
        }
        (self.sim_finish_at_ns - self.sim_first_token_at_ns) / (self.tokens.len() - 1) as f64
    }

    /// Whether this request met its deadline (no deadline counts as met;
    /// a shed or failed request never does — it was given up on).
    pub fn met_deadline(&self) -> bool {
        !self.shed
            && !self.failed
            && self.deadline_ns.map_or(true, |d| self.sim_finish_at_ns <= d)
    }
}

/// A prompt whose prefill completed on a [`ShardRole::Prefill`] shard,
/// awaiting KV-cache transfer to a decode shard.  The request inside keeps
/// its *original* arrival time (end-to-end latency spans the whole
/// pipeline); the coordinator prices the KV link and delivers the handoff
/// via [`Server::submit_handoff`].
#[derive(Debug, Clone)]
pub struct Handoff {
    /// The original request (arrival/deadline untouched; the prompt rides
    /// along so the decode engine can rebuild its hidden state).
    pub req: Request,
    /// Intrinsic simulated prefill cost charged on the prefill shard, ns.
    pub sim_prefill_ns: f64,
    /// Absolute prefill-shard clock time the prompt finished, ns.
    pub prefill_finish_at_ns: f64,
}

/// Decode-side bookkeeping for one received [`Handoff`], keyed by request
/// id until admission (and re-inserted if the scheduler re-queues the
/// running request — the KV cache stays resident on this shard, so
/// re-admission must keep skipping prefill).
#[derive(Debug, Clone, Copy)]
struct HandoffMeta {
    sim_prefill_ns: f64,
    original_arrival_ns: f64,
    kv_transfer_ns: f64,
    /// Whether this handoff was already counted into the shard's
    /// `handoffs`/`kv_transfer_ns` stats (a re-queued handoff is
    /// re-admitted but crossed the link only once).
    counted: bool,
}

/// Per-shard utilization accounting (one entry per worker).
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Group label this shard belongs to (`"unified"` outside a
    /// [`crate::config::ClusterSpec`]-built cluster).
    pub group: String,
    /// The shard's lifecycle role.
    pub role: ShardRole,
    /// Requests this shard retired (completed, shed, or handed off to a
    /// decode shard).
    pub requests: usize,
    /// Tokens this shard generated.
    pub tokens: usize,
    /// Summed simulated RACAM service time of this shard's requests, ns.
    pub sim_ns: f64,
    /// Host wall-clock of this shard's serving loop, ns.
    pub wall_ns: f64,
    /// Final value of this shard's simulated clock (its makespan), ns.
    pub sim_clock_ns: f64,
    /// Simulated time this shard sat idle waiting for arrivals, ns.
    pub sim_idle_ns: f64,
    /// Decode iterations executed.
    pub decode_iterations: usize,
    /// Mean fraction of batch slots occupied across decode iterations
    /// (1.0 = the shard decoded at full batch the whole run).
    pub occupancy: f64,
    /// Prefill steps executed (one per admitted prompt under whole-prompt
    /// prefill; one per chunk under a chunked [`ServingPolicy`]).
    pub prefill_chunks: usize,
    /// Simulated time prefill steps charged while at least one fully
    /// prefilled request sat waiting to decode — the decode stall a
    /// chunked policy bounds and a whole-prompt policy lets grow with the
    /// longest prompt.
    pub chunk_stall_ns: f64,
    /// Running requests re-queued by the scheduler's preemption hook.
    pub preemptions: usize,
    /// Running requests shed by the scheduler's preemption hook.
    pub shed: usize,
    /// Handoffs this shard participated in: prompts handed *out* on a
    /// prefill shard, prefilled requests received on a decode shard (zero
    /// on unified shards).
    pub handoffs: usize,
    /// Simulated KV-cache transfer time charged on this (decode) shard's
    /// requests, ns — the cost of the prefill→decode link.
    pub kv_transfer_ns: f64,
}

impl ShardStats {
    /// Fraction of the shard's simulated makespan spent serving (vs idle).
    pub fn utilization(&self) -> f64 {
        if self.sim_clock_ns <= 0.0 {
            return 0.0;
        }
        (self.sim_clock_ns - self.sim_idle_ns) / self.sim_clock_ns
    }
}

/// Fault/recovery accounting of one serving run (all zero on a
/// fault-free run).  Populated by the coordinator's recovery loop; a
/// plain [`Server`] run always reports the default.  Everything here is
/// simulated-deterministic and compared by
/// [`ServerReport::sim_divergence`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultTally {
    /// Shards that crashed during the run.
    pub crashed_shards: usize,
    /// Crash-evacuation re-dispatches (every `FaultRequeue`).
    pub retries: usize,
    /// Requests that terminated `failed`.
    pub failed: usize,
    /// Evacuated requests shed by the degradation controller.
    pub degrade_shed: usize,
    /// KV transfers re-sent after a link-outage interruption.
    pub kv_retries: usize,
    /// Per-group surviving-capacity timeline: one `(detection time ns,
    /// group label, fresh-prompt-capable shards still alive cluster-wide)`
    /// entry per shard crash, in detection order.
    pub capacity_timeline: Vec<(f64, String, usize)>,
}

impl FaultTally {
    /// True when no fault or recovery action was recorded.
    pub fn is_empty(&self) -> bool {
        self.crashed_shards == 0
            && self.retries == 0
            && self.failed == 0
            && self.degrade_shed == 0
            && self.kv_retries == 0
            && self.capacity_timeline.is_empty()
    }

    fn merge(&mut self, other: &FaultTally) {
        self.crashed_shards += other.crashed_shards;
        self.retries += other.retries;
        self.failed += other.failed;
        self.degrade_shed += other.degrade_shed;
        self.kv_retries += other.kv_retries;
        self.capacity_timeline.extend(other.capacity_timeline.iter().cloned());
    }

    /// First simulated divergence against another tally, if any (f64
    /// timestamps compare bit-for-bit — same contract as
    /// [`ServerReport::sim_divergence`]).
    fn divergence(&self, other: &FaultTally) -> Option<String> {
        if self.crashed_shards != other.crashed_shards
            || self.retries != other.retries
            || self.failed != other.failed
            || self.degrade_shed != other.degrade_shed
            || self.kv_retries != other.kv_retries
        {
            return Some("fault tally counters differ".into());
        }
        if self.capacity_timeline.len() != other.capacity_timeline.len() {
            return Some("capacity timeline length differs".into());
        }
        for ((ta, ga, ca), (tb, gb, cb)) in
            self.capacity_timeline.iter().zip(&other.capacity_timeline)
        {
            if ta.to_bits() != tb.to_bits() || ga != gb || ca != cb {
                return Some(format!("capacity timeline entry differs ({ga} vs {gb})"));
            }
        }
        None
    }
}

/// Aggregate serving report (single shard or merged across shards).
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub results: Vec<RequestResult>,
    pub sim_tokens_per_s: f64,
    pub wall_tokens_per_s: f64,
    pub total_tokens: usize,
    /// Per-shard utilization; one entry for a plain [`Server`] run, one per
    /// worker for a [`super::Coordinator`] run.
    pub shards: Vec<ShardStats>,
    /// Fault/recovery accounting (default on a fault-free run).
    pub faults: FaultTally,
}

impl ServerReport {
    /// Compare every *simulated* (deterministic) quantity of two reports
    /// bit-for-bit — per-request results, per-shard stats, totals —
    /// ignoring host wall-clock fields, which differ even between two
    /// runs of the same engine.  Returns a description of the first
    /// divergence, or `None` when the reports are simulation-identical.
    ///
    /// This is the single comparator behind every engine-equivalence
    /// gate (the `Server` unit tests, `tests/engine_equivalence.rs`, and
    /// `exp scale`'s in-run check), so a field added to
    /// [`RequestResult`] or [`ShardStats`] only needs to be wired here
    /// once to be covered everywhere.
    pub fn sim_divergence(&self, other: &ServerReport) -> Option<String> {
        if self.results.len() != other.results.len() {
            return Some(format!(
                "result count {} vs {}",
                self.results.len(),
                other.results.len()
            ));
        }
        if self.total_tokens != other.total_tokens {
            return Some(format!("total tokens {} vs {}", self.total_tokens, other.total_tokens));
        }
        for (x, y) in self.results.iter().zip(&other.results) {
            if x.id != y.id {
                return Some(format!("result ids {} vs {}", x.id, y.id));
            }
            if x.tokens != y.tokens {
                return Some(format!("req {}: tokens differ", x.id));
            }
            if x.prompt_tokens != y.prompt_tokens || x.shed != y.shed || x.failed != y.failed {
                return Some(format!("req {}: prompt_tokens/shed/failed differ", x.id));
            }
            if x.deadline_ns.map(f64::to_bits) != y.deadline_ns.map(f64::to_bits) {
                return Some(format!("req {}: deadline differs", x.id));
            }
            for (name, u, v) in [
                ("sim_ttft_ns", x.sim_ttft_ns, y.sim_ttft_ns),
                ("sim_total_ns", x.sim_total_ns, y.sim_total_ns),
                ("arrival_ns", x.arrival_ns, y.arrival_ns),
                ("sim_first_token_at_ns", x.sim_first_token_at_ns, y.sim_first_token_at_ns),
                ("sim_finish_at_ns", x.sim_finish_at_ns, y.sim_finish_at_ns),
            ] {
                if u.to_bits() != v.to_bits() {
                    return Some(format!("req {}: {name} {u} vs {v}", x.id));
                }
            }
        }
        if self.shards.len() != other.shards.len() {
            return Some(format!("shard count {} vs {}", self.shards.len(), other.shards.len()));
        }
        for (s, t) in self.shards.iter().zip(&other.shards) {
            if s.shard != t.shard || s.group != t.group || s.role != t.role {
                return Some(format!("shard {}: identity differs", s.shard));
            }
            if s.requests != t.requests
                || s.tokens != t.tokens
                || s.decode_iterations != t.decode_iterations
                || s.prefill_chunks != t.prefill_chunks
                || s.preemptions != t.preemptions
                || s.shed != t.shed
                || s.handoffs != t.handoffs
            {
                return Some(format!("shard {}: counters differ", s.shard));
            }
            for (name, u, v) in [
                ("sim_ns", s.sim_ns, t.sim_ns),
                ("sim_clock_ns", s.sim_clock_ns, t.sim_clock_ns),
                ("sim_idle_ns", s.sim_idle_ns, t.sim_idle_ns),
                ("occupancy", s.occupancy, t.occupancy),
                ("chunk_stall_ns", s.chunk_stall_ns, t.chunk_stall_ns),
                ("kv_transfer_ns", s.kv_transfer_ns, t.kv_transfer_ns),
            ] {
                if u.to_bits() != v.to_bits() {
                    return Some(format!("shard {}: {name} {u} vs {v}", s.shard));
                }
            }
        }
        if let Some(d) = self.faults.divergence(&other.faults) {
            return Some(d);
        }
        None
    }

    /// Merge per-shard reports into one, re-sorting results by request id.
    /// Shards run concurrently, so both clocks use the makespan — the
    /// slowest shard — rather than a sum: `wall_ns` is the
    /// coordinator-level wall clock, and simulated throughput divides by
    /// the largest per-shard simulated clock.
    pub fn merge(reports: Vec<ServerReport>, wall_ns: f64) -> ServerReport {
        let mut results: Vec<RequestResult> = Vec::new();
        let mut shards: Vec<ShardStats> = Vec::new();
        let mut faults = FaultTally::default();
        for r in reports {
            results.extend(r.results);
            shards.extend(r.shards);
            faults.merge(&r.faults);
        }
        results.sort_by_key(|r| r.id);
        shards.sort_by_key(|s| s.shard);
        let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let sim_makespan_ns = shards
            .iter()
            .map(|s| if s.sim_clock_ns > 0.0 { s.sim_clock_ns } else { s.sim_ns })
            .fold(0.0f64, f64::max);
        ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_makespan_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results,
            shards,
            faults,
        }
    }
}

/// Future-arrival queue entry: min-heap by (arrival, id) for determinism.
#[derive(Debug, PartialEq, Eq)]
struct FutureReq {
    arrival_ns: u64,
    id: u64,
    req: Request,
}

impl Ord for FutureReq {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.arrival_ns, self.id).cmp(&(other.arrival_ns, other.id))
    }
}

impl PartialOrd for FutureReq {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One serving worker (see module docs).
///
/// The third parameter is the telemetry sink: [`NopRecorder`] by default,
/// whose empty inline `record` monomorphizes every hook away — the
/// uninstrumented hot loop, unchanged.  Swap it with
/// [`Server::with_recorder`] to capture the simulated event stream; a
/// recorder is a pure observer, so simulated results stay bit-identical
/// either way (`tests/engine_equivalence.rs` pins this).
pub struct Server<E: TokenEngine, S: Scheduler = FcfsBatcher, R: Recorder = NopRecorder> {
    engine: E,
    racam: RacamSystem,
    spec: LlmSpec,
    scheduler: S,
    max_batch: usize,
    shard_id: usize,
    /// Group label for per-group reporting (set by the cluster builder).
    group: String,
    /// Lifecycle role: a `Prefill` shard hands finished prompts off
    /// instead of decoding them; a `Decode` shard admits handoffs straight
    /// into the decode phase.
    role: ShardRole,
    /// Prompts whose prefill finished on this (prefill) shard, awaiting
    /// pickup by the coordinator ([`Server::take_handoffs`]).
    handoffs_out: Vec<Handoff>,
    /// Received handoffs' accounting, keyed by request id until admission.
    handoff_meta: HashMap<u64, HandoffMeta>,
    /// How the iteration engine schedules prefill and preemption.
    policy: ServingPolicy,
    /// Requests whose simulated arrival time has not been reached yet.
    future: BinaryHeap<Reverse<FutureReq>>,
    /// Scratch buffer the admission path hands to
    /// [`Scheduler::next_batch_into`] each round (drained after use), so
    /// admission performs no per-round allocation.
    admit_scratch: Vec<Request>,
    /// Live intake: requests sent here are admitted mid-run.
    intake: Option<mpsc::Receiver<Request>>,
    /// Simulated per-token decode cost per context bucket, kept across
    /// runs so repeated runs (and long-lived shards) reuse priced buckets.
    decode_cache: HashMap<u64, LatencyBreakdown>,
    /// Simulated prefill cost per prompt-length bucket (same granularity
    /// as the decode cache), so live traffic with many distinct prompt
    /// lengths prices a bounded number of prefill shapes.
    prefill_cache: HashMap<u64, LatencyBreakdown>,
    /// Per-shard fault schedule and runtime state (inactive by default —
    /// see `docs/robustness.md`).
    faults: ShardFaults,
    /// Reduced-channel pricing runtime, installed by
    /// [`Server::fault_derate`] and consulted once the channel-loss
    /// event fires.
    derate: Option<Box<DerateRuntime>>,
    /// Floor for the simulated clock at the start of a run (0 normally;
    /// the coordinator's recovery loop sets a continuation wave's floor
    /// to the shard's previous clock so time never runs backwards).
    clock_floor_ns: f64,
    /// Telemetry sink (zero-sized no-op by default).
    recorder: R,
}

/// One declared brownout window on this shard's simulated clock (see
/// [`crate::config::FaultEvent::Brownout`]).
#[derive(Debug, Clone, Copy)]
struct BrownoutWindow {
    start_ns: f64,
    end_ns: f64,
    slowdown: f64,
    /// Whether the window's onset was already announced to telemetry.
    announced: bool,
}

/// Per-shard fault schedule plus runtime state.  Inactive (`!armed`) by
/// default: the serving loop then never touches it, so a fault-free run
/// is instruction-for-instruction today's path.
#[derive(Debug, Default)]
struct ShardFaults {
    /// Fast guard for the per-round fault step.
    armed: bool,
    /// Pending permanent crash (consumed when it fires).
    crash_at_ns: Option<f64>,
    /// Declared brownout windows, in declaration order.
    brownouts: Vec<BrownoutWindow>,
    /// Pending channel-loss activation (consumed when it fires).
    derate_at_ns: Option<f64>,
    /// The crash fired: the shard accepts no more work.
    crashed: bool,
    /// Simulated clock at which the crash was detected — the round edge
    /// at or after `crash_at_ns` (faults are observed at round
    /// granularity in *both* engines; the calendar engine's decode
    /// stretches break at the next fault edge to keep that identical).
    detected_at_ns: f64,
    /// Channel-loss repricing is active.
    derated: bool,
    /// Requests evacuated by the crash, awaiting coordinator
    /// re-dispatch ([`Server::take_evacuated`]).
    evacuated: Vec<Request>,
}

/// Channel-loss pricing runtime: a [`RacamSystem`] backed by a reduced-
/// channel mapping service, with its own cost caches — the full-channel
/// caches stay intact so costs charged before the loss keep their exact
/// values.
struct DerateRuntime {
    racam: RacamSystem,
    channels_left: u32,
    decode_cache: HashMap<u64, LatencyBreakdown>,
    prefill_cache: HashMap<u64, LatencyBreakdown>,
}

/// Where one batch member is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// `done` prompt tokens have been consumed by prefill steps so far.
    Prefill { done: u64 },
    /// Prompt fully prefilled; the member decodes in lockstep.
    Decode,
}

/// Consecutive prefill steps a staged prompt may be bypassed by
/// shorter-remaining prompts before it gets priority (chunked mode's
/// anti-starvation bound): a long prompt's prefill stretches by at most
/// this factor under a sustained stream of short arrivals, instead of
/// being starved indefinitely.
const MAX_PREFILL_BYPASSES: u32 = 4;

/// The staged member owed priority by the anti-starvation rule: the
/// oldest (min admission seq) member bypassed [`MAX_PREFILL_BYPASSES`]
/// or more chunks in a row.  One definition shared by the oracle's
/// linear selection and the calendar engine's armed bypass path — engine
/// bit-identity depends on the two never drifting.
fn bypass_candidate(running: &[Running]) -> Option<usize> {
    running
        .iter()
        .enumerate()
        .filter(|(_, r)| {
            matches!(r.phase, Phase::Prefill { .. }) && r.bypassed >= MAX_PREFILL_BYPASSES
        })
        .min_by_key(|(_, r)| r.seq)
        .map(|(i, _)| i)
}

/// Per-member decode pricing schedule, precomputed at admission and
/// refreshed only when the context crosses a bucket edge — so the decode
/// hot loop performs no `ctx_bucket` arithmetic and no cache lookups
/// (the calendar engine's per-token work is an add and a compare).
#[derive(Debug, Clone, Copy)]
struct DecodeSchedule {
    /// Per-token simulated cost at the member's current context bucket, ns.
    cost_ns: f64,
    /// Decode tokens that may still be charged at `cost_ns` before the
    /// context crosses into the next pricing bucket (0 = must refresh).
    tokens_to_edge: u64,
}

impl DecodeSchedule {
    const STALE: DecodeSchedule = DecodeSchedule { cost_ns: 0.0, tokens_to_edge: 0 };
}

struct Running {
    req: Request,
    phase: Phase,
    /// The handoff bookkeeping this member was admitted with (decode
    /// shards); rides along so a scheduler re-queue can re-install it —
    /// the KV cache stays resident, so re-admission skips prefill and the
    /// result keeps the original arrival and prefill cost.
    handoff: Option<HandoffMeta>,
    /// Admission order across the whole run: the prefill-step tiebreaker,
    /// and the strict prefill order under whole-prompt mode — independent
    /// of slot shuffling in the `running` vector.
    seq: u64,
    /// Consecutive prefill steps this staged prompt was passed over for a
    /// shorter one (chunked mode); at [`MAX_PREFILL_BYPASSES`] it takes
    /// priority.  Reset each time the prompt receives a chunk.
    bypassed: u32,
    /// [`ctx_bucket`] of the prompt length, fixed at admission so the
    /// final prefill span never recomputes it.
    prompt_bucket: u64,
    /// Cached decode pricing (see [`DecodeSchedule`]).
    sched: DecodeSchedule,
    /// The scheduler's preemption horizon for this request (`None` =
    /// consult `should_preempt` every iteration), captured at admission.
    /// Only meaningful while the active policy enables preemption.
    preempt_horizon: Option<f64>,
    hidden: Vec<f32>,
    tokens: Vec<u32>,
    sim_ns: f64,
    sim_ttft_ns: f64,
    arrival_ns: f64,
    first_token_at_ns: f64,
}

impl Running {
    /// Retire into a [`RequestResult`], recycling the hidden-state buffer
    /// through `pool` (per-request wall time is attributed once at report
    /// assembly — see [`RequestResult::wall_ns`]).
    fn retire(mut self, sim_finish_at_ns: f64, shed: bool, pool: &mut Vec<Vec<f32>>) -> RequestResult {
        let mut hidden = std::mem::take(&mut self.hidden);
        hidden.clear();
        pool.push(hidden);
        RequestResult {
            id: self.req.id,
            prompt_tokens: self.req.prompt.len(),
            tokens: self.tokens,
            sim_ttft_ns: self.sim_ttft_ns,
            sim_total_ns: self.sim_ns,
            wall_ns: 0.0,
            arrival_ns: self.arrival_ns,
            sim_first_token_at_ns: self.first_token_at_ns,
            sim_finish_at_ns,
            deadline_ns: self.req.deadline_ns.map(|d| d as f64),
            shed,
            failed: false,
        }
    }

    /// Prompt tokens still to prefill (1 floor matches `next_prefill`).
    fn prefill_remaining(&self) -> u64 {
        match self.phase {
            Phase::Prefill { done } => (self.req.prompt.len() as u64).max(1).saturating_sub(done),
            Phase::Decode => 0,
        }
    }
}

/// Mutable state of one serving run, shared by both engines.  Alongside
/// the batch and the accounting counters it carries the calendar engine's
/// *indexes* — the structures that replace the oracle's per-iteration
/// linear scans:
///
/// * `srpt` — staged prompts keyed by (SRPT remaining work, admission
///   seq); lazily invalidated entries are filtered on pop via `slot_of`.
/// * `horizon` — running members keyed by their preemption horizon (the
///   deadline, for EDF), so a decode stretch knows the earliest time the
///   scheduler's verdict could change without scanning the batch.
/// * `slot_of` — seq → current index in `running`, maintained across the
///   ordered removes / swap-removes both engines share.
/// * `staged` / `decoding` — phase population counters, so "is any prompt
///   staged" and "how many members decode" are O(1).
///
/// (The third index the tentpole names — release time — is the server's
/// long-standing `future` arrival heap.)
struct LoopState {
    running: Vec<Running>,
    done: Vec<RequestResult>,
    sim_now_ns: f64,
    sim_idle_ns: f64,
    decode_iterations: usize,
    occupancy_sum: f64,
    prefill_chunks: usize,
    chunk_stall_ns: f64,
    preemptions: usize,
    shed_count: usize,
    handed_off: usize,
    handoffs_in: usize,
    kv_transfer_ns: f64,
    admit_seq: u64,
    stalled_requeue_rounds: usize,
    /// Whether the active policy consults the preemption hook.
    preempt_enabled: bool,
    /// Prefill chunk bound (floored at 1), captured from the policy when
    /// the run began; `None` = whole-prompt prefill.
    chunk_tokens: Option<u64>,
    /// Whether prefill advances in bounded chunks (SRPT keys) or whole
    /// prompts (admission-order keys).
    chunked: bool,
    /// Recycled hidden-state buffers: retired members return theirs here
    /// and admission reuses them, so steady-state serving allocates a
    /// bounded pool (≤ max batch) instead of one buffer per request.
    hidden_pool: Vec<Vec<f32>>,
    /// seq → index in `running`.
    slot_of: HashMap<u64, usize>,
    /// Staged-prefill index: (remaining-work key, seq), min-heap.
    srpt: BinaryHeap<Reverse<(u64, u64)>>,
    /// Preemption-horizon index: (horizon f64 bits, seq), min-heap.
    /// Non-negative f64 bit patterns order like the floats themselves.
    horizon: BinaryHeap<Reverse<(u64, u64)>>,
    /// Running members currently mid-prefill.
    staged: usize,
    /// Running members currently decoding.
    decoding: usize,
    /// Running members whose scheduler gave no horizon (`None`): while any
    /// exist under a preempting policy, decode steps one iteration at a
    /// time so the scheduler is consulted exactly like the oracle.
    horizon_unknown: usize,
    /// Set when a bypass-starved staged prompt may exist (armed by the
    /// bypass accounting, cleared when a scan finds none) — so the common
    /// prefill path never scans for starvation.
    bypass_ready: bool,
}

impl LoopState {
    fn new(preempt_enabled: bool, chunk_tokens: Option<u64>) -> LoopState {
        let chunked = chunk_tokens.is_some();
        LoopState {
            running: Vec::new(),
            done: Vec::new(),
            sim_now_ns: 0.0,
            sim_idle_ns: 0.0,
            decode_iterations: 0,
            occupancy_sum: 0.0,
            prefill_chunks: 0,
            chunk_stall_ns: 0.0,
            preemptions: 0,
            shed_count: 0,
            handed_off: 0,
            handoffs_in: 0,
            kv_transfer_ns: 0.0,
            admit_seq: 0,
            stalled_requeue_rounds: 0,
            preempt_enabled,
            chunk_tokens,
            chunked,
            hidden_pool: Vec::new(),
            slot_of: HashMap::new(),
            srpt: BinaryHeap::new(),
            horizon: BinaryHeap::new(),
            staged: 0,
            decoding: 0,
            horizon_unknown: 0,
            bypass_ready: false,
        }
    }

    /// The SRPT key of a staged member: remaining work under chunking,
    /// admission order alone under whole-prompt prefill (every key 0, so
    /// the seq tiebreak reproduces the legacy strict admission order).
    fn srpt_key(&self, r: &Running) -> u64 {
        if self.chunked {
            r.prefill_remaining()
        } else {
            0
        }
    }

    /// Admit a member: appends to `running` and indexes it.
    fn push_member(&mut self, m: Running) {
        let idx = self.running.len();
        self.slot_of.insert(m.seq, idx);
        match m.phase {
            Phase::Prefill { .. } => {
                self.staged += 1;
                let key = self.srpt_key(&m);
                self.srpt.push(Reverse((key, m.seq)));
            }
            Phase::Decode => self.decoding += 1,
        }
        if self.preempt_enabled {
            match m.preempt_horizon {
                Some(h) => self.horizon.push(Reverse((h.to_bits(), m.seq))),
                None => self.horizon_unknown += 1,
            }
        }
        self.running.push(m);
    }

    fn note_removed(&mut self, m: &Running) {
        match m.phase {
            Phase::Prefill { .. } => self.staged -= 1,
            Phase::Decode => self.decoding -= 1,
        }
        if self.preempt_enabled && m.preempt_horizon.is_none() {
            self.horizon_unknown -= 1;
        }
        self.slot_of.remove(&m.seq);
        // Stale srpt/horizon entries are filtered on pop via `slot_of`.
    }

    /// Ordered removal (preemption / prefill-retire paths — preserves the
    /// batch order the oracle's `Vec::remove` produces).
    fn remove_member(&mut self, idx: usize) -> Running {
        let m = self.running.remove(idx);
        self.note_removed(&m);
        for j in idx..self.running.len() {
            self.slot_of.insert(self.running[j].seq, j);
        }
        m
    }

    /// Swap removal (the decode-retire path — same order evolution as the
    /// oracle's `swap_remove`).
    fn swap_remove_member(&mut self, idx: usize) -> Running {
        let m = self.running.swap_remove(idx);
        self.note_removed(&m);
        if idx < self.running.len() {
            self.slot_of.insert(self.running[idx].seq, idx);
        }
        m
    }

    /// Transition a member from prefill to decode (keeps the counters and
    /// the member's slot; its stale srpt entry filters out on pop).
    fn set_decoding(&mut self, idx: usize) {
        debug_assert!(matches!(self.running[idx].phase, Phase::Prefill { .. }));
        self.staged -= 1;
        self.decoding += 1;
        self.running[idx].phase = Phase::Decode;
    }

    /// Pop the staged member the next prefill step should advance: the
    /// indexed form of `next_prefill`'s SRPT scan (min (remaining, seq)
    /// chunked; min seq whole-prompt).  Stale entries — members that
    /// finished prefill, left the batch, or advanced a chunk since they
    /// were pushed — are discarded as they surface.
    fn pop_srpt(&mut self) -> Option<usize> {
        while let Some(Reverse((key, seq))) = self.srpt.peek().copied() {
            let Some(&idx) = self.slot_of.get(&seq) else {
                self.srpt.pop();
                continue;
            };
            let valid = matches!(self.running[idx].phase, Phase::Prefill { .. })
                && self.srpt_key(&self.running[idx]) == key;
            if valid {
                return Some(idx);
            }
            self.srpt.pop();
        }
        None
    }

    /// Index of the staged member the next prefill step should advance,
    /// honouring the anti-starvation bypass rule exactly like the oracle's
    /// scan: a member bypassed [`MAX_PREFILL_BYPASSES`] chunks in a row
    /// takes priority (oldest first); otherwise SRPT from the heap.
    fn select_prefill(&mut self) -> Option<usize> {
        if self.chunked && self.bypass_ready {
            if let Some(idx) = bypass_candidate(&self.running) {
                return Some(idx);
            }
            self.bypass_ready = false;
        }
        self.pop_srpt()
    }

    /// Retire every decoding member that completed its token budget —
    /// the end-of-round scan both engines share (ascending-index
    /// swap-remove walk, so the batch-order evolution is identical).
    fn retire_finished(&mut self) {
        let mut i = 0;
        while i < self.running.len() {
            if matches!(self.running[i].phase, Phase::Decode)
                && self.running[i].tokens.len() >= self.running[i].req.max_new_tokens
            {
                let finish_at = self.sim_now_ns;
                let r = self.swap_remove_member(i);
                let res = r.retire(finish_at, false, &mut self.hidden_pool);
                self.done.push(res);
            } else {
                i += 1;
            }
        }
    }

    /// Smallest preemption horizon over the running batch, from the
    /// deadline-keyed index.  `None` means a member's scheduler demands
    /// per-iteration consultation (fast-forward must not skip its calls);
    /// `Some(f64::INFINITY)` means no verdict can ever change.
    fn min_horizon(&mut self) -> Option<f64> {
        if !self.preempt_enabled {
            return Some(f64::INFINITY);
        }
        if self.horizon_unknown > 0 {
            return None;
        }
        while let Some(Reverse((bits, seq))) = self.horizon.peek().copied() {
            if self.slot_of.contains_key(&seq) {
                return Some(f64::from_bits(bits));
            }
            self.horizon.pop();
        }
        Some(f64::INFINITY)
    }
}

impl<E: TokenEngine> Server<E, FcfsBatcher> {
    /// `spec` names the LLM whose kernel shapes the RACAM clock prices
    /// (the toy engine generates real tokens; the simulator accounts what
    /// the full-size model would cost on RACAM hardware).
    pub fn new(engine: E, racam: RacamSystem, spec: LlmSpec, max_batch: usize) -> Self {
        let scheduler = FcfsBatcher::new(max_batch);
        Server::with_scheduler(engine, racam, spec, max_batch, scheduler)
    }
}

impl<E: TokenEngine, S: Scheduler> Server<E, S> {
    /// A server with an explicit admission policy.
    pub fn with_scheduler(
        engine: E,
        racam: RacamSystem,
        spec: LlmSpec,
        max_batch: usize,
        scheduler: S,
    ) -> Self {
        assert!(max_batch >= 1);
        Server {
            engine,
            racam,
            spec,
            scheduler,
            max_batch,
            shard_id: 0,
            group: "unified".into(),
            role: ShardRole::Unified,
            handoffs_out: Vec::new(),
            handoff_meta: HashMap::new(),
            policy: ServingPolicy::default(),
            future: BinaryHeap::new(),
            admit_scratch: Vec::new(),
            intake: None,
            decode_cache: HashMap::new(),
            prefill_cache: HashMap::new(),
            faults: ShardFaults::default(),
            derate: None,
            clock_floor_ns: 0.0,
            recorder: NopRecorder,
        }
    }
}

impl<E: TokenEngine, S: Scheduler, R: Recorder> Server<E, S, R> {
    /// Swap the telemetry sink (e.g. a
    /// [`TraceRecorder`](crate::telemetry::TraceRecorder) for
    /// `--trace-out`).  Builder-style because it changes the server's
    /// type: recording is a compile-time property, which is what makes
    /// the disabled path free.
    pub fn with_recorder<R2: Recorder>(self, recorder: R2) -> Server<E, S, R2> {
        Server {
            engine: self.engine,
            racam: self.racam,
            spec: self.spec,
            scheduler: self.scheduler,
            max_batch: self.max_batch,
            shard_id: self.shard_id,
            group: self.group,
            role: self.role,
            handoffs_out: self.handoffs_out,
            handoff_meta: self.handoff_meta,
            policy: self.policy,
            future: self.future,
            admit_scratch: self.admit_scratch,
            intake: self.intake,
            decode_cache: self.decode_cache,
            prefill_cache: self.prefill_cache,
            faults: self.faults,
            derate: self.derate,
            clock_floor_ns: self.clock_floor_ns,
            recorder,
        }
    }

    /// The telemetry sink (borrow the recorded events after a run).
    pub fn recorder(&self) -> &R {
        &self.recorder
    }

    /// Mutable access to the telemetry sink (e.g. to drain events
    /// between runs).
    pub fn recorder_mut(&mut self) -> &mut R {
        &mut self.recorder
    }

    /// Set the serving policy (chunked prefill, preemption).  The default
    /// reproduces the whole-prefill schedule bit-for-bit.
    pub fn set_policy(&mut self, policy: ServingPolicy) {
        debug_assert!(policy.validate().is_ok(), "invalid serving policy: {policy:?}");
        self.policy = policy;
    }

    /// Builder-style [`Server::set_policy`].
    pub fn with_policy(mut self, policy: ServingPolicy) -> Self {
        self.set_policy(policy);
        self
    }

    /// The active serving policy.
    pub fn policy(&self) -> ServingPolicy {
        self.policy
    }

    /// Queue a request.  Requests with a positive [`Request::arrival_ns`]
    /// stay invisible to the scheduler until the simulated clock reaches
    /// their arrival.
    pub fn submit(&mut self, req: Request) {
        if req.arrival_ns > 0 {
            self.future.push(Reverse(FutureReq { arrival_ns: req.arrival_ns, id: req.id, req }));
        } else {
            self.scheduler.submit(req);
        }
    }

    /// Open (or replace) the live intake channel and return its sender.
    /// While any sender is alive, `run_to_completion` keeps serving —
    /// blocking when idle — and only returns after the last sender drops.
    pub fn open_intake(&mut self) -> mpsc::Sender<Request> {
        let (tx, rx) = mpsc::channel();
        self.intake = Some(rx);
        tx
    }

    /// Requests waiting for admission (queued now or arriving later).
    pub fn pending(&self) -> usize {
        self.scheduler.pending() + self.future.len()
    }

    /// Access the simulated-hardware pipeline (e.g. to persist its mapping
    /// cache after a run, §7 amortization).
    pub fn racam(&self) -> &RacamSystem {
        &self.racam
    }

    /// Priced decode context buckets held in server state.
    pub fn decode_cache_len(&self) -> usize {
        self.decode_cache.len()
    }

    /// Label this worker for per-shard reporting (set by the coordinator).
    pub(crate) fn set_shard(&mut self, id: usize) {
        self.shard_id = id;
    }

    /// Set the group label used in per-group reporting.
    pub(crate) fn set_group(&mut self, label: &str) {
        self.group = label.to_string();
    }

    /// Set this shard's lifecycle role (default [`ShardRole::Unified`]).
    pub(crate) fn set_role(&mut self, role: ShardRole) {
        self.role = role;
    }

    /// This shard's lifecycle role.
    pub fn role(&self) -> ShardRole {
        self.role
    }

    /// Drain the prompts this (prefill) shard has finished and queued for
    /// KV transfer.  Called by the coordinator between the prefill and
    /// decode phases of a disaggregated run.
    pub fn take_handoffs(&mut self) -> Vec<Handoff> {
        std::mem::take(&mut self.handoffs_out)
    }

    /// This shard's group label (per-group fault distribution).
    pub(crate) fn group_label(&self) -> &str {
        &self.group
    }

    /// Schedule a permanent crash at a simulated time (coordinator
    /// fault distribution — see [`super::Coordinator::set_faults`]).
    pub(crate) fn fault_crash_at(&mut self, at_ns: f64) {
        self.faults.crash_at_ns = Some(at_ns);
        self.faults.armed = true;
    }

    /// Schedule a brownout window: every simulated cost charged while
    /// the clock is inside `[start_ns, end_ns)` is multiplied by
    /// `slowdown` (≥ 1).  Overlapping windows compose multiplicatively.
    pub(crate) fn fault_brownout(&mut self, start_ns: f64, end_ns: f64, slowdown: f64) {
        self.faults.brownouts.push(BrownoutWindow {
            start_ns,
            end_ns,
            slowdown,
            announced: false,
        });
        self.faults.armed = true;
    }

    /// Schedule a DRAM channel-loss at a simulated time: from the first
    /// round edge at or past `at_ns`, bucket pricing switches to
    /// `racam` (a [`RacamSystem`] over the reduced-channel mapping
    /// service) with fresh cost caches.
    pub(crate) fn fault_derate(&mut self, at_ns: f64, racam: RacamSystem, channels_left: u32) {
        self.derate = Some(Box::new(DerateRuntime {
            racam,
            channels_left,
            decode_cache: HashMap::new(),
            prefill_cache: HashMap::new(),
        }));
        self.faults.derate_at_ns = Some(at_ns);
        self.faults.armed = true;
    }

    /// Whether this shard's crash has fired.
    pub(crate) fn fault_crashed(&self) -> bool {
        self.faults.crashed
    }

    /// Simulated clock at which the crash was detected (meaningful only
    /// when [`Server::fault_crashed`]).
    pub(crate) fn crash_detected_at(&self) -> f64 {
        self.faults.detected_at_ns
    }

    /// Drain the requests evacuated by a crash, for re-dispatch.
    pub(crate) fn take_evacuated(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.faults.evacuated)
    }

    /// Floor the next run's starting clock (recovery continuation waves
    /// resume from the shard's previous makespan instead of 0).
    pub(crate) fn set_clock_floor(&mut self, ns: f64) {
        self.clock_floor_ns = ns;
    }

    /// Combined brownout slowdown factor at a simulated time (1.0 =
    /// full speed).  Windows compose multiplicatively in declaration
    /// order; both engines sample this at identical timestamps, so the
    /// composition order never differs.
    fn fault_factor(&self, at_ns: f64) -> f64 {
        let mut f = 1.0f64;
        for b in &self.faults.brownouts {
            if b.start_ns <= at_ns && at_ns < b.end_ns {
                f *= b.slowdown;
            }
        }
        f
    }

    /// Apply due fault events at a round edge — the only place fault
    /// state transitions.  Called at the top of both `round_calendar`
    /// and `round_oracle` before any scheduling, so the two engines
    /// observe every fault at the same simulated clock (the calendar
    /// engine's decode stretches break at the next crash/derate edge to
    /// keep the round boundaries aligned).
    fn fault_step(&mut self, st: &mut LoopState) {
        let now = st.sim_now_ns;
        for b in &mut self.faults.brownouts {
            if !b.announced && b.start_ns <= now {
                b.announced = true;
                self.recorder.record(Event::instant(EventKind::Brownout, now, NO_REQ, b.slowdown));
            }
        }
        if self.faults.derate_at_ns.is_some_and(|at| now >= at) {
            self.faults.derate_at_ns = None;
            self.faults.derated = true;
            // Every decode schedule was priced at the full channel
            // count: force a re-price from the derated runtime.  (A
            // STALE schedule refreshes without a BucketEdge event — a
            // repricing is not a context-bucket crossing.)
            for r in st.running.iter_mut() {
                if matches!(r.phase, Phase::Decode) {
                    r.sched = DecodeSchedule::STALE;
                }
            }
            let left = self.derate.as_ref().map_or(0.0, |d| d.channels_left as f64);
            self.recorder.record(Event::instant(EventKind::ChannelLoss, now, NO_REQ, left));
        }
        if self.faults.crash_at_ns.is_some_and(|at| now >= at) {
            self.faults.crash_at_ns = None;
            self.faults.crashed = true;
            self.faults.detected_at_ns = now;
            // Evacuate the running batch in slot order.  Generation
            // state and resident KV die with the shard, so requests go
            // back whole — the same recompute semantics as
            // `Preemption::Requeue`, but across shards.
            while !st.running.is_empty() {
                let mut r = st.remove_member(0);
                let mut hidden = std::mem::take(&mut r.hidden);
                hidden.clear();
                st.hidden_pool.push(hidden);
                if let Some(m) = r.handoff {
                    // The evacuated request keeps its original arrival
                    // so end-to-end latency spans the whole pipeline.
                    r.req.arrival_ns = m.original_arrival_ns as u64;
                }
                self.faults.evacuated.push(r.req);
            }
            self.evacuate_queues();
            self.recorder.record(Event::instant(
                EventKind::ShardCrash,
                now,
                NO_REQ,
                self.faults.evacuated.len() as f64,
            ));
        }
    }

    /// Move everything queued on this (crashed) shard into the
    /// evacuation buffer: scheduler backlog, future arrivals, and
    /// not-yet-collected outbound handoffs.
    fn evacuate_queues(&mut self) {
        self.scheduler.drain_pending_into(&mut self.faults.evacuated);
        while let Some(Reverse(f)) = self.future.pop() {
            self.faults.evacuated.push(f.req);
        }
        for h in self.handoffs_out.drain(..) {
            self.faults.evacuated.push(h.req);
        }
        // Undelivered handoffs lose their KV with the shard; restore
        // the original arrival the link-transfer release had rewritten.
        for req in &mut self.faults.evacuated {
            if let Some(m) = self.handoff_meta.remove(&req.id) {
                req.arrival_ns = m.original_arrival_ns as u64;
            }
        }
        self.handoff_meta.clear();
    }

    /// A round on a crashed shard: no scheduling — late arrivals are
    /// evacuated for the coordinator and the loop idles to completion.
    fn crashed_round(&mut self, st: &mut LoopState, block: bool) -> Result<Round> {
        self.drain_intake(st.sim_now_ns);
        self.evacuate_queues();
        match self.idle_step(st, 0, 0, 0, false, block)? {
            RoundIdle::Continue => Ok(Round::Continue),
            RoundIdle::Finished => Ok(Round::Finished),
            RoundIdle::WouldBlock => Ok(Round::WouldBlock),
        }
    }

    /// Deliver a prefilled request to this (decode) shard.  The request is
    /// released to the scheduler once the simulated clock reaches
    /// *prefill finish + KV transfer*; on admission it skips prefill, its
    /// accounting carries the prefill shard's intrinsic cost, and the
    /// transfer time is charged to [`ShardStats::kv_transfer_ns`].
    pub fn submit_handoff(&mut self, handoff: Handoff, kv_transfer_ns: f64) {
        let Handoff { mut req, sim_prefill_ns, prefill_finish_at_ns } = handoff;
        self.handoff_meta.insert(
            req.id,
            HandoffMeta {
                sim_prefill_ns,
                original_arrival_ns: req.arrival_ns as f64,
                kv_transfer_ns,
                counted: false,
            },
        );
        // The KV cache lands on this shard's clock at prefill finish +
        // transfer; ceil keeps the release causal (never before the data).
        req.arrival_ns = (prefill_finish_at_ns + kv_transfer_ns).ceil() as u64;
        self.submit(req);
    }

    /// Simulated prefill cost for a prompt length.  The kernel *shape* is
    /// priced once per [`ctx_bucket`] so live traffic with arbitrary
    /// prompt lengths triggers a bounded number of mapping searches; the
    /// bucket cost is then scaled linearly to the actual token count so
    /// short prompts are not charged a whole bucket's prefill (attention's
    /// quadratic share makes this a mild overestimate below the boundary,
    /// never the ~bucket/len inflation of charging the ceiling).
    fn prefill_cost(&mut self, prompt_len: u64) -> Result<LatencyBreakdown> {
        let len = prompt_len.max(1);
        self.prefill_cost_bucketed(len, ctx_bucket(len))
    }

    /// [`Server::prefill_cost`] with the bucket id supplied by the caller
    /// (admission precomputes each request's prompt bucket, so the final
    /// prefill span never recomputes it).
    fn prefill_cost_bucketed(&mut self, len: u64, bucket: u64) -> Result<LatencyBreakdown> {
        debug_assert_eq!(bucket, ctx_bucket(len), "caller-supplied bucket must match");
        let per_bucket = if self.faults.derated {
            let Some(d) = self.derate.as_mut() else {
                anyhow::bail!("channel-loss fault active without a derated runtime");
            };
            if let Some(c) = d.prefill_cache.get(&bucket) {
                *c
            } else {
                let cost = stage_latency(&d.racam, &prefill_kernels(&self.spec, bucket))?;
                d.prefill_cache.insert(bucket, cost);
                cost
            }
        } else if let Some(c) = self.prefill_cache.get(&bucket) {
            *c
        } else {
            let cost = stage_latency(&self.racam, &prefill_kernels(&self.spec, bucket))?;
            self.prefill_cache.insert(bucket, cost);
            cost
        };
        Ok(per_bucket.scaled(len as f64 / bucket as f64))
    }

    /// Simulated cost of prefilling prompt tokens `[from, to)`, as the
    /// difference of the bucket-scaled whole-prefill costs at the two
    /// boundaries (`to_bucket` = the bucket of `to`, supplied by the
    /// caller — the final span reuses the admission-time prompt bucket).
    /// A single `[0, len)` span is *exactly* the legacy whole-prefill
    /// charge (bit-for-bit), and a prompt's chunk spans telescope to the
    /// same total up to float rounding.
    fn prefill_span_cost_to(
        &mut self,
        from: u64,
        to: u64,
        to_bucket: u64,
    ) -> Result<LatencyBreakdown> {
        let hi = self.prefill_cost_bucketed(to.max(1), to_bucket)?;
        if from == 0 {
            return Ok(hi);
        }
        let lo = self.prefill_cost(from)?;
        // The per-token bucket cost is non-decreasing in context (attention
        // grows superlinearly), so the difference is non-negative.  If a
        // hardware/model preset ever violates that, chunk costs would stop
        // telescoping to the whole-prefill cost — fail loudly in debug
        // builds instead of silently undercharging, and clamp in release.
        debug_assert!(
            hi.total_ns() >= lo.total_ns(),
            "prefill pricing non-monotone: cost({to}) = {} < cost({from}) = {} — \
             chunked prefill would undercharge",
            hi.total_ns(),
            lo.total_ns()
        );
        Ok(LatencyBreakdown::new(
            (hi.pim_ns - lo.pim_ns).max(0.0),
            (hi.io_ns - lo.io_ns).max(0.0),
        ))
    }

    /// Simulated per-token decode cost at a context length, priced once
    /// per bucket.
    fn decode_cost(&mut self, ctx: u64) -> Result<LatencyBreakdown> {
        self.decode_cost_bucket(ctx_bucket(ctx))
    }

    /// [`Server::decode_cost`] keyed directly by the bucket id (the
    /// calendar engine's refresh path, which already knows the bucket).
    fn decode_cost_bucket(&mut self, bucket: u64) -> Result<LatencyBreakdown> {
        if self.faults.derated {
            let Some(d) = self.derate.as_mut() else {
                anyhow::bail!("channel-loss fault active without a derated runtime");
            };
            if let Some(c) = d.decode_cache.get(&bucket) {
                return Ok(*c);
            }
            let cost = stage_latency(&d.racam, &decode_kernels(&self.spec, bucket))?;
            d.decode_cache.insert(bucket, cost);
            return Ok(cost);
        }
        if let Some(c) = self.decode_cache.get(&bucket) {
            return Ok(*c);
        }
        let cost = stage_latency(&self.racam, &decode_kernels(&self.spec, bucket))?;
        self.decode_cache.insert(bucket, cost);
        Ok(cost)
    }

    /// Drain everything currently available on the intake channel without
    /// blocking.  Live submissions arriving "in the past" of the simulated
    /// clock are clamped to now — they arrive when received.
    fn drain_intake(&mut self, sim_now_ns: f64) {
        // Take the receiver out so `submit` can borrow self mutably.
        let Some(rx) = self.intake.take() else { return };
        let mut open = true;
        loop {
            match rx.try_recv() {
                Ok(req) => self.submit(Self::clamp_arrival(req, sim_now_ns)),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if open {
            self.intake = Some(rx);
        }
    }

    /// Clamp an in-past arrival to the current simulated time, comparing
    /// against the exact `f64` clock.  The integer arrival is truncated
    /// (never rounded up past `sim_now_ns`), so a clamped request releases
    /// immediately instead of being pushed up to 1 ns into the future —
    /// the old `ceil()`-based clamp could park it in the future-arrival
    /// heap and skew queueing-delay accounting.
    fn clamp_arrival(mut req: Request, sim_now_ns: f64) -> Request {
        if (req.arrival_ns as f64) < sim_now_ns {
            req.arrival_ns = sim_now_ns as u64;
        }
        req
    }

    /// Move future requests whose arrival time has come into the scheduler.
    fn release_due(&mut self, sim_now_ns: f64) {
        while self.future.peek().is_some_and(|r| r.0.arrival_ns as f64 <= sim_now_ns) {
            let Some(Reverse(f)) = self.future.pop() else { break };
            self.recorder.record(Event::instant(
                EventKind::ArrivalRelease,
                sim_now_ns,
                f.id,
                f.arrival_ns as f64,
            ));
            self.scheduler.submit(f.req);
        }
    }

    /// Index of the batch member the next prefill step should advance.
    /// Whole-prompt mode goes strictly in admission order (the legacy
    /// schedule, reproduced bit-for-bit).  Chunked mode picks the member
    /// with the fewest *remaining* prompt tokens (ties by admission
    /// order): shortest-remaining-first is what makes chunking pay off for
    /// TTFT — a short prompt admitted behind a half-prefilled long one
    /// completes its single chunk and starts decoding instead of queueing
    /// behind every remaining chunk of the long prompt.  A member bypassed
    /// [`MAX_PREFILL_BYPASSES`] steps in a row takes priority (oldest
    /// first), so a sustained stream of short arrivals can stretch a long
    /// prompt's prefill but never starve it.
    fn next_prefill(running: &[Running], chunked: bool) -> Option<usize> {
        if chunked {
            if let Some(idx) = bypass_candidate(running) {
                return Some(idx);
            }
        }
        running
            .iter()
            .enumerate()
            .filter_map(|(i, r)| match r.phase {
                Phase::Prefill { done } => {
                    let remaining = (r.req.prompt.len() as u64).max(1).saturating_sub(done);
                    Some((i, if chunked { remaining } else { 0 }, r.seq))
                }
                Phase::Decode => None,
            })
            .min_by_key(|&(_, remaining, seq)| (remaining, seq))
            .map(|(i, _, _)| i)
    }

    /// Drain all submitted requests to completion; with an open intake,
    /// keep serving live submissions until every sender is dropped.
    ///
    /// Dispatches on [`ServingPolicy::engine`]: the event-calendar engine
    /// with decode fast-forward (the default), or the per-iteration
    /// reference engine.  Both produce bit-identical simulated results —
    /// timestamps, costs, tokens, per-shard stats; only host wall time
    /// differs (see module docs and `docs/serving.md`).
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        // The one wall timer of a single-shard run ("one timer per poll
        // batch", PR 6): everything else on this path is simulated time.
        #[allow(clippy::disallowed_methods)]
        let wall_start = Instant::now(); // detcheck: allow(wall-clock) -- the single per-run wall timer; feeds ServerReport::wall_ns only, never simulated results
        let mut st = self.begin_state();
        loop {
            match self.round(&mut st, true)? {
                Round::Continue => {}
                Round::Finished => break,
                // Blocking rounds park on the intake inside `idle_step`
                // instead of reporting back.
                Round::WouldBlock => unreachable!("blocking round reported WouldBlock"),
            }
        }
        Ok(self.finish_report(st, wall_start.elapsed().as_nanos() as f64))
    }

    /// Fresh loop state for a run, with every vector that grows with the
    /// request stream pre-sized from what the run can already see (queued
    /// + future requests, batch capacity) — the serving loop itself then
    /// amortizes no growth on the hot path.
    fn begin_state(&mut self) -> LoopState {
        // Chunk floor at 1: a zero-token chunk would never advance
        // prefill (`ServingPolicy::validate` rejects it, but don't trust
        // callers with an infinite loop).
        let chunk_tokens = self.policy.prefill_chunk_tokens.map(|c| c.max(1));
        let mut st = LoopState::new(self.policy.preempt, chunk_tokens);
        // Recovery continuation waves resume from the shard's previous
        // makespan (0.0 — a no-op — outside the recovery loop).
        st.sim_now_ns = self.clock_floor_ns;
        let expected = self.scheduler.pending() + self.future.len();
        st.running.reserve(self.max_batch.min(expected.max(1)));
        st.hidden_pool.reserve(self.max_batch);
        st.slot_of.reserve(self.max_batch);
        if self.role == ShardRole::Prefill {
            // Every request leaves as a handoff instead of a result.
            self.handoffs_out.reserve(expected);
        } else {
            st.done.reserve(expected);
        }
        st
    }

    /// One scheduling round of the configured engine (see [`Round`]).
    fn round(&mut self, st: &mut LoopState, block: bool) -> Result<Round> {
        match self.policy.engine {
            EngineKind::Calendar => self.round_calendar(st, block),
            EngineKind::Oracle => self.round_oracle(st, block),
        }
    }

    /// Admit new work into free batch slots (continuous batching).
    /// Admission only *stages* the request; its prefill cost is charged by
    /// the prefill steps.  Returns how many requests were admitted.
    fn admit(&mut self, st: &mut LoopState) -> usize {
        let slots = self.max_batch.saturating_sub(st.running.len());
        // Recycled scratch: the scheduler appends into it, the loop
        // drains it — no per-round `Vec` churn.
        let mut batch = std::mem::take(&mut self.admit_scratch);
        debug_assert!(batch.is_empty());
        self.scheduler.next_batch_into(slots, &mut batch);
        let admitted = batch.len();
        for req in batch.drain(..) {
            self.recorder.record(Event::instant(
                EventKind::Admit,
                st.sim_now_ns,
                req.id,
                self.scheduler.pending() as f64,
            ));
            // Recycled hidden-state buffer (retired members return theirs
            // to the pool).
            let mut hidden = st.hidden_pool.pop().unwrap_or_default();
            self.engine.embed_prompt_into(&req.prompt, &mut hidden);
            // A received handoff skips prefill: its prompt was already
            // prefilled on the prefill shard, whose intrinsic cost (and
            // original arrival, for end-to-end latency) carries over;
            // the KV-link transfer is charged to this shard's stats
            // once, however many times a re-queue re-admits it.
            let mut meta = self.handoff_meta.remove(&req.id);
            if let Some(m) = &mut meta {
                if !m.counted {
                    st.handoffs_in += 1;
                    st.kv_transfer_ns += m.kv_transfer_ns;
                    m.counted = true;
                }
            }
            let (phase, carried_ns, arrival_ns) = match &meta {
                Some(m) => (Phase::Decode, m.sim_prefill_ns, m.original_arrival_ns),
                None => (Phase::Prefill { done: 0 }, 0.0, req.arrival_ns as f64),
            };
            let preempt_horizon =
                if self.policy.preempt { self.scheduler.preempt_horizon(&req, 0) } else { None };
            st.push_member(Running {
                phase,
                handoff: meta,
                seq: st.admit_seq,
                bypassed: 0,
                prompt_bucket: ctx_bucket(req.prompt.len() as u64),
                sched: DecodeSchedule::STALE,
                preempt_horizon,
                hidden,
                // Sized once: the token vector never reallocates mid-run.
                tokens: Vec::with_capacity(req.max_new_tokens),
                sim_ns: carried_ns,
                sim_ttft_ns: carried_ns,
                arrival_ns,
                first_token_at_ns: st.sim_now_ns,
                req,
            });
            st.admit_seq += 1;
        }
        self.admit_scratch = batch;
        admitted
    }

    /// Preemption scan: consult the scheduler about every running request
    /// (newly admitted ones included, so dead-on-arrival work sheds before
    /// paying any prefill).  Returns (requeued, shed) counts this round.
    fn preempt_scan(&mut self, st: &mut LoopState) -> (usize, usize) {
        let mut requeued = 0usize;
        let mut shed_round = 0usize;
        if self.policy.preempt {
            let mut i = 0;
            while i < st.running.len() {
                let r = &st.running[i];
                match self.scheduler.should_preempt(&r.req, r.tokens.len(), st.sim_now_ns) {
                    Preemption::Keep => i += 1,
                    Preemption::Requeue => {
                        st.preemptions += 1;
                        requeued += 1;
                        self.recorder.record(Event::instant(
                            EventKind::Preempt,
                            st.sim_now_ns,
                            st.running[i].req.id,
                            st.running[i].tokens.len() as f64,
                        ));
                        // Generation state is dropped: re-admission
                        // re-prefills (recompute-style preemption).  A
                        // re-queued *handoff* keeps its bookkeeping —
                        // its KV cache is resident on this shard, so
                        // re-admission skips prefill and the result
                        // keeps the original arrival and prefill cost.
                        let mut r = st.remove_member(i);
                        let mut hidden = std::mem::take(&mut r.hidden);
                        hidden.clear();
                        st.hidden_pool.push(hidden);
                        if let Some(m) = r.handoff {
                            self.handoff_meta.insert(r.req.id, m);
                        }
                        self.scheduler.submit(r.req);
                    }
                    Preemption::Shed => {
                        st.shed_count += 1;
                        shed_round += 1;
                        self.recorder.record(Event::instant(
                            EventKind::Shed,
                            st.sim_now_ns,
                            st.running[i].req.id,
                            st.running[i].tokens.len() as f64,
                        ));
                        let r = st.remove_member(i);
                        let res = r.retire(st.sim_now_ns, true, &mut st.hidden_pool);
                        st.done.push(res);
                    }
                }
            }
        }
        (requeued, shed_round)
    }

    /// Charge one prefill step (a bounded chunk, or the whole prompt) to
    /// member `idx`, with the bypass bookkeeping, phase transition, and
    /// zero-token / prefill-shard retirement both engines share.
    fn prefill_step_at(
        &mut self,
        st: &mut LoopState,
        idx: usize,
        chunk_tokens: Option<u64>,
    ) -> Result<()> {
        let decoders_waiting = st.decoding > 0;
        let prefilled = match st.running[idx].phase {
            Phase::Prefill { done } => done,
            Phase::Decode => unreachable!("prefill step on a decoding member"),
        };
        // Empty prompts still price one token (prefill_cost floors
        // at 1), so `total` floors too and every prompt finishes.
        let total = (st.running[idx].req.prompt.len() as u64).max(1);
        let end = match chunk_tokens {
            None => total,
            Some(c) => (prefilled + c).min(total),
        };
        let finished = end >= total;
        // The final span's upper bucket is the admission-time prompt
        // bucket; intermediate chunk boundaries bucket on the fly.
        let hi_bucket = if finished { st.running[idx].prompt_bucket } else { ctx_bucket(end) };
        let span = self.prefill_span_cost_to(prefilled, end, hi_bucket)?;
        let mut step_ns = span.total_ns();
        if !self.faults.brownouts.is_empty() {
            // Brownout: the chunk's charge stretches by the slowdown at
            // the time the step starts.  The `!= 1.0` guard keeps the
            // fault-free float sequence bit-identical.
            let f = self.fault_factor(st.sim_now_ns);
            if f != 1.0 {
                step_ns *= f;
            }
        }
        self.recorder.record(Event::span(
            EventKind::PrefillChunk,
            st.sim_now_ns,
            step_ns,
            st.running[idx].req.id,
            (end - prefilled) as f64,
        ));
        st.sim_now_ns += step_ns;
        st.prefill_chunks += 1;
        if decoders_waiting {
            st.chunk_stall_ns += step_ns;
        }
        if chunk_tokens.is_some() {
            // Anti-starvation accounting: every other staged prompt was
            // passed over for this chunk.  Arm the bypass path only when
            // a member actually crossed the threshold, so the common
            // selection never scans for starvation.
            let mut armed = false;
            for (i, r) in st.running.iter_mut().enumerate() {
                if i != idx && matches!(r.phase, Phase::Prefill { .. }) {
                    r.bypassed = r.bypassed.saturating_add(1);
                    armed |= r.bypassed >= MAX_PREFILL_BYPASSES;
                }
            }
            st.running[idx].bypassed = 0;
            st.bypass_ready = armed;
        }
        {
            let r = &mut st.running[idx];
            r.sim_ns += step_ns;
            r.sim_ttft_ns += step_ns;
        }
        if finished {
            // Prompt fully prefilled: the first token lands at the
            // end of the next decode iteration; until then, the
            // prefill end stamps first-token time (exact for
            // prefill-only requests).
            st.running[idx].first_token_at_ns = st.sim_now_ns;
            st.set_decoding(idx);
        } else {
            st.running[idx].phase = Phase::Prefill { done: end };
            // Re-index the advanced prompt under its new remaining work.
            let key = st.srpt_key(&st.running[idx]);
            let seq = st.running[idx].seq;
            st.srpt.push(Reverse((key, seq)));
        }
        if finished && st.running[idx].req.max_new_tokens == 0 {
            // Nothing to decode: retire immediately.
            let r = st.remove_member(idx);
            let res = r.retire(st.sim_now_ns, false, &mut st.hidden_pool);
            st.done.push(res);
        } else if finished && self.role == ShardRole::Prefill {
            // Prefill-only shard: the finished prompt leaves for a
            // decode shard instead of decoding here.  The decode
            // shard emits the request's (single) result; this shard
            // only counts the handoff.
            let mut r = st.remove_member(idx);
            let mut hidden = std::mem::take(&mut r.hidden);
            hidden.clear();
            st.hidden_pool.push(hidden);
            st.handed_off += 1;
            self.recorder.record(Event::instant(
                EventKind::HandoffDispatch,
                st.sim_now_ns,
                r.req.id,
                r.req.prompt.len() as f64,
            ));
            self.handoffs_out.push(Handoff {
                sim_prefill_ns: r.sim_ttft_ns,
                prefill_finish_at_ns: st.sim_now_ns,
                req: r.req,
            });
        }
        Ok(())
    }

    /// Handle a round that ends with an empty batch: the withholding /
    /// requeue-livelock bails, the idle clock jump to the next arrival,
    /// and the intake wait — shared by both engines verbatim.
    ///
    /// `block` selects the intake behavior when no simulated work
    /// remains: a standalone run parks the thread on `recv` (the
    /// long-standing behavior), while an executor-driven batch probes
    /// with `try_recv` and reports [`RoundIdle::WouldBlock`] so the
    /// worker can run other shards instead of stalling the pool.  The
    /// two modes admit the same requests at the same simulated times —
    /// only host-thread scheduling differs.
    fn idle_step(
        &mut self,
        st: &mut LoopState,
        admitted: usize,
        requeued: usize,
        shed_round: usize,
        prefill_progressed: bool,
        block: bool,
    ) -> Result<RoundIdle> {
        if self.scheduler.pending() > 0 {
            if admitted == 0 && requeued == 0 && shed_round == 0 {
                // The scheduler returned nothing while work is
                // queued and every batch slot is free: that
                // violates the `Scheduler::next_batch` contract
                // and would spin this loop forever.  (A round that
                // re-queued or shed running work made progress —
                // the freed slots refill next round.)
                anyhow::bail!(
                    "scheduler withheld {} queued request(s) with {} free slots",
                    self.scheduler.pending(),
                    self.max_batch
                );
            }
            if admitted > 0 && requeued == admitted && shed_round == 0 && !prefill_progressed {
                // Everything admitted this round was immediately
                // re-queued before any simulated progress: the
                // round ends in exactly the state it started in.
                // A stateful policy may legitimately defer a
                // request's first few admissions, so tolerate a
                // bounded streak of such rounds; a policy that
                // keeps it up violates the `should_preempt`
                // contract and would spin this loop forever.
                st.stalled_requeue_rounds += 1;
                if st.stalled_requeue_rounds >= 8 {
                    anyhow::bail!(
                        "scheduler re-queued all {requeued} admitted request(s) \
                         without advancing the clock for \
                         {} consecutive rounds",
                        st.stalled_requeue_rounds
                    );
                }
                return Ok(RoundIdle::Continue);
            }
            // Everything admitted this round retired at prefill
            // (zero-token requests) or was shed; keep draining.
            st.stalled_requeue_rounds = 0;
            return Ok(RoundIdle::Continue);
        }
        if let Some(r) = self.future.peek() {
            // Idle until the next arrival: jump the clock.
            let next = r.0.arrival_ns as f64;
            if next > st.sim_now_ns {
                self.recorder.record(Event::span(
                    EventKind::IdleJump,
                    st.sim_now_ns,
                    next - st.sim_now_ns,
                    NO_REQ,
                    0.0,
                ));
                st.sim_idle_ns += next - st.sim_now_ns;
                st.sim_now_ns = next;
            }
            return Ok(RoundIdle::Continue);
        }
        if let Some(rx) = self.intake.take() {
            // No simulated work left but the intake is open.  A
            // disconnect leaves the intake closed (`None`).
            if block {
                // Park on the channel (host wall time, not simulated
                // time).
                if let Ok(req) = rx.recv() {
                    self.intake = Some(rx);
                    self.submit(Self::clamp_arrival(req, st.sim_now_ns));
                }
                return Ok(RoundIdle::Continue);
            }
            // Executor mode: never park a pooled worker on one shard's
            // channel.
            return match rx.try_recv() {
                Ok(req) => {
                    self.intake = Some(rx);
                    self.submit(Self::clamp_arrival(req, st.sim_now_ns));
                    Ok(RoundIdle::Continue)
                }
                Err(mpsc::TryRecvError::Empty) => {
                    self.intake = Some(rx);
                    Ok(RoundIdle::WouldBlock)
                }
                // Closed: the next round observes everything drained and
                // finishes.
                Err(mpsc::TryRecvError::Disconnected) => Ok(RoundIdle::Continue),
            };
        }
        Ok(RoundIdle::Finished)
    }

    /// One decode round of the calendar engine: a single lockstep
    /// iteration (`fast = false` — the oracle-equivalent step over the
    /// decoding subset of a mixed batch), or a fast-forwarded *stretch*
    /// (`fast = true` — every member decoding, nothing admissible) that
    /// jumps iteration by iteration to the nearest calendar event:
    ///
    /// * a member completing its token budget (batch-membership change),
    /// * a pricing-bucket edge (the per-token cost changes),
    /// * an arrival release crossing the advancing clock,
    /// * the scheduler's preemption horizon.
    ///
    /// Within a stretch the per-token work is the token engine step plus
    /// two float adds and two compares — no admission call, no preemption
    /// scan, no prefill selection, no bucket hashing, no retire scan, no
    /// per-member wall-clock reads.  The clock and every member's service
    /// time accumulate with the *same sequence of f64 additions* as the
    /// oracle, so the fast path is bit-identical, not just close.
    fn decode_round(&mut self, st: &mut LoopState, fast: bool, horizon: Option<f64>) -> Result<()> {
        // Refresh stale pricing schedules (bucket edge crossed, or member
        // newly decoding) — the only place decode pricing is looked up.
        for i in 0..st.running.len() {
            let r = &st.running[i];
            if !matches!(r.phase, Phase::Decode) || r.sched.tokens_to_edge > 0 {
                continue;
            }
            let ctx = r.req.prompt.len() as u64 + r.tokens.len() as u64 + 1;
            let bucket = ctx_bucket(ctx);
            // A member whose *priced* schedule ran out crossed a pricing-
            // bucket edge; a STALE schedule (cost 0) is a fresh admission,
            // not an edge.  Calendar-only: the oracle prices per iteration
            // and never materializes an edge to cross.
            if r.sched.cost_ns > 0.0 {
                self.recorder.record(Event::instant(
                    EventKind::BucketEdge,
                    st.sim_now_ns,
                    r.req.id,
                    bucket as f64,
                ));
            }
            let cost = self.decode_cost_bucket(bucket)?;
            st.running[i].sched =
                DecodeSchedule { cost_ns: cost.total_ns(), tokens_to_edge: bucket + 1 - ctx };
        }

        // Lockstep: the clock advances by the slowest member's per-token
        // cost, constant until the next bucket edge.
        let mut maxc = 0.0f64;
        for r in &st.running {
            if matches!(r.phase, Phase::Decode) {
                maxc = maxc.max(r.sched.cost_ns);
            }
        }
        // The stretch bound: iterations to the nearest deterministic
        // event.  `horizon = None` (a scheduler without the purity
        // promise) forces single-stepping so its hooks run per iteration.
        let mut k = 1u64;
        if fast && horizon.is_some() {
            k = u64::MAX;
            for r in &st.running {
                let rem = (r.req.max_new_tokens - r.tokens.len()) as u64;
                k = k.min(rem).min(r.sched.tokens_to_edge);
            }
        }
        let next_arrival = self.future.peek().map(|r| r.0.arrival_ns as f64);
        let horizon_ns = horizon.unwrap_or(f64::INFINITY);
        let occ = st.decoding as f64 / self.max_batch as f64;
        let stretch_start_ns = st.sim_now_ns;
        // Fault calendar entries: a pending crash or channel-loss must
        // end the stretch at the first iteration edge at or past its
        // onset — the oracle observes faults at its per-iteration round
        // edges, and the next shared `fault_step` has to run at the same
        // clock.  Brownout windows need no break: the factor below is
        // sampled per iteration, exactly like the oracle's rounds.
        let fault_edge = match (self.faults.crash_at_ns, self.faults.derate_at_ns) {
            (None, None) => f64::INFINITY,
            (a, b) => a.unwrap_or(f64::INFINITY).min(b.unwrap_or(f64::INFINITY)),
        };
        let slowed = !self.faults.brownouts.is_empty();

        let mut iters = 0u64;
        while iters < k {
            let factor = if slowed { self.fault_factor(st.sim_now_ns) } else { 1.0 };
            let mut new_first = false;
            for r in st.running.iter_mut() {
                if !matches!(r.phase, Phase::Decode) {
                    continue;
                }
                let token = self.engine.step_in_place(&mut r.hidden)?;
                r.tokens.push(token);
                // `== 1.0` guard: the fault-free (and out-of-window)
                // float sequence stays bit-identical to the unfaulted
                // engine; inside a window, max(cᵢ·f) = max(cᵢ)·f for a
                // shared positive factor, so the clock advance below
                // matches the oracle's per-member max bit-for-bit.
                r.sim_ns +=
                    if factor == 1.0 { r.sched.cost_ns } else { r.sched.cost_ns * factor };
                new_first |= r.tokens.len() == 1;
            }
            st.decode_iterations += 1;
            st.occupancy_sum += occ;
            st.sim_now_ns += if factor == 1.0 { maxc } else { maxc * factor };
            iters += 1;
            if new_first {
                // First decoded token lands at the end of this
                // iteration on the shard clock.
                for r in st.running.iter_mut() {
                    if matches!(r.phase, Phase::Decode) && r.tokens.len() == 1 {
                        r.first_token_at_ns = st.sim_now_ns;
                    }
                }
            }
            // Clock-dependent calendar events end the stretch: an arrival
            // became due, the preemption horizon was crossed, or a
            // pending fault's onset was reached.
            if next_arrival.is_some_and(|a| a <= st.sim_now_ns)
                || st.sim_now_ns > horizon_ns
                || st.sim_now_ns >= fault_edge
            {
                break;
            }
        }

        // One event per stretch, however many iterations it fast-forwarded
        // (`count` carries the multiplicity — `Metrics::absorb_events`
        // fans it back out to per-iteration occupancy samples).
        if iters > 0 {
            self.recorder.record(Event {
                kind: EventKind::DecodeStretch,
                at_ns: stretch_start_ns,
                dur_ns: st.sim_now_ns - stretch_start_ns,
                req: NO_REQ,
                value: st.decoding as f64,
                count: iters,
            });
        }

        // Advance every decoder's pricing schedule by the stretch length.
        // (No wall-clock read here: the per-stretch `Instant` pair moved
        // up to the run boundary — see `finish_report` — so the per-token
        // work is exactly the adds and compares above.)
        for r in st.running.iter_mut() {
            if matches!(r.phase, Phase::Decode) {
                r.sched.tokens_to_edge -= iters;
            }
        }
        Ok(())
    }

    /// Assemble the final report from a drained loop state.  `wall_ns` is
    /// the host wall time spent inside the serving loop — one `Instant`
    /// pair around the whole run (or accumulated across executor batches),
    /// the only wall-clock reads a run performs.  Per-request `wall_ns` is
    /// that total apportioned by each request's share of simulated service
    /// time (see [`RequestResult::wall_ns`]).
    fn finish_report(&self, st: LoopState, wall_ns: f64) -> ServerReport {
        let LoopState {
            mut done,
            sim_now_ns,
            sim_idle_ns,
            decode_iterations,
            occupancy_sum,
            prefill_chunks,
            chunk_stall_ns,
            preemptions,
            shed_count,
            handed_off,
            handoffs_in,
            kv_transfer_ns,
            ..
        } = st;
        done.sort_by_key(|r| r.id);
        let total_tokens: usize = done.iter().map(|r| r.tokens.len()).sum();
        let sim_ns: f64 = done.iter().map(|r| r.sim_total_ns).sum();
        if sim_ns > 0.0 {
            let scale = wall_ns / sim_ns;
            for r in &mut done {
                r.wall_ns = r.sim_total_ns * scale;
            }
        } else if !done.is_empty() {
            // Degenerate run (e.g. all zero-cost): equal shares.
            let share = wall_ns / done.len() as f64;
            for r in &mut done {
                r.wall_ns = share;
            }
        }
        let stats = ShardStats {
            shard: self.shard_id,
            group: self.group.clone(),
            role: self.role,
            requests: done.len() + handed_off,
            tokens: total_tokens,
            sim_ns,
            wall_ns,
            sim_clock_ns: sim_now_ns,
            sim_idle_ns,
            decode_iterations,
            occupancy: if decode_iterations == 0 {
                0.0
            } else {
                occupancy_sum / decode_iterations as f64
            },
            prefill_chunks,
            chunk_stall_ns,
            preemptions,
            shed: shed_count,
            handoffs: handed_off + handoffs_in,
            kv_transfer_ns,
        };
        ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_now_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results: done,
            shards: vec![stats],
            faults: FaultTally::default(),
        }
    }

    /// One round of the per-iteration reference engine: the complete
    /// schedule — intake drain, arrival release, admission call,
    /// preemption scan, linear prefill selection, one lockstep decode
    /// iteration with per-member bucket lookups, retire scan.  This is the
    /// equivalence oracle the calendar engine is pinned against; it also
    /// serves schedulers whose hooks are stateful.
    fn round_oracle(&mut self, st: &mut LoopState, block: bool) -> Result<Round> {
        let chunk_tokens = st.chunk_tokens;
        if self.faults.armed {
            self.fault_step(st);
            if self.faults.crashed {
                return self.crashed_round(st, block);
            }
        }
        self.drain_intake(st.sim_now_ns);
        self.release_due(st.sim_now_ns);
        let admitted = self.admit(st);
        let (requeued, shed_round) = self.preempt_scan(st);

        // Prefill steps.  Whole-prompt mode drains every staged prompt
        // back-to-back in admission order — the legacy schedule.
        // Chunked mode advances one bounded chunk of the staged prompt
        // with the least remaining work, then falls through to a
        // decode iteration, so running decodes (and short prompts)
        // interleave with a long prompt instead of stalling behind it.
        let mut prefill_progressed = false;
        while let Some(idx) = Self::next_prefill(&st.running, chunk_tokens.is_some()) {
            prefill_progressed = true;
            self.prefill_step_at(st, idx, chunk_tokens)?;
            if chunk_tokens.is_some() {
                break;
            }
        }

        if st.running.is_empty() {
            return match self
                .idle_step(st, admitted, requeued, shed_round, prefill_progressed, block)?
            {
                RoundIdle::Continue => Ok(Round::Continue),
                RoundIdle::Finished => Ok(Round::Finished),
                RoundIdle::WouldBlock => Ok(Round::WouldBlock),
            };
        }

        // Real work happened this round: any requeue stall is over.
        st.stalled_requeue_rounds = 0;

        // A chunked policy can leave the whole batch mid-prefill; no
        // decode iteration runs until at least one prompt completes.
        let decoding = st.running.iter().filter(|r| matches!(r.phase, Phase::Decode)).count();
        if decoding == 0 {
            return Ok(Round::Continue);
        }

        // One decode iteration across the fully prefilled batch
        // members.  They step in lockstep, so the shard clock advances
        // by the slowest member's per-token cost; each member's own
        // service-time accounting still charges its own bucket.
        // Occupancy counts only decoding members: under a chunked
        // policy, mid-prefill members hold slots but are not decoding
        // (with whole-prompt prefill the two counts are identical).
        st.decode_iterations += 1;
        st.occupancy_sum += decoding as f64 / self.max_batch as f64;
        // Brownout slowdown sampled at the iteration start — the same
        // timestamp the calendar stretch samples, so the two engines
        // multiply identical factors.  The `!= 1.0` guards keep the
        // fault-free float sequence bit-identical.
        let factor = if self.faults.brownouts.is_empty() {
            1.0
        } else {
            self.fault_factor(st.sim_now_ns)
        };
        let mut iteration_ns = 0.0f64;
        for i in 0..st.running.len() {
            if !matches!(st.running[i].phase, Phase::Decode) {
                continue;
            }
            let r = &mut st.running[i];
            let token = self.engine.step_in_place(&mut r.hidden)?;
            r.tokens.push(token);

            let ctx = r.req.prompt.len() as u64 + r.tokens.len() as u64;
            let mut cost = self.decode_cost(ctx)?.total_ns();
            if factor != 1.0 {
                cost *= factor;
            }
            st.running[i].sim_ns += cost;
            iteration_ns = iteration_ns.max(cost);
        }
        st.sim_now_ns += iteration_ns;
        // The oracle emits one single-iteration stretch per decode round
        // (the calendar engine's fast path coalesces these with `count`).
        self.recorder.record(Event {
            kind: EventKind::DecodeStretch,
            at_ns: st.sim_now_ns - iteration_ns,
            dur_ns: iteration_ns,
            req: NO_REQ,
            value: decoding as f64,
            count: 1,
        });
        for r in &mut st.running {
            if matches!(r.phase, Phase::Decode) && r.tokens.len() == 1 {
                // First decoded token lands at the end of this
                // iteration on the shard clock.
                r.first_token_at_ns = st.sim_now_ns;
            }
        }

        // Retire finished requests.
        st.retire_finished();
        Ok(Round::Continue)
    }

    /// One round of the event-calendar engine (the default).  The round
    /// structure is the oracle's, but:
    ///
    /// * prefill selection pops the SRPT index instead of scanning the
    ///   batch (bypass-starved prompts keep their exact priority — the
    ///   scan only runs while one is armed);
    /// * a *uniform lockstep-decode stretch* — every member decoding and
    ///   no admission possible before a membership change — fast-forwards
    ///   through [`Server::decode_round`] to the next calendar event
    ///   instead of paying the full round per token;
    /// * decode pricing comes from each member's precomputed bucket
    ///   schedule, refreshed only at bucket edges.
    fn round_calendar(&mut self, st: &mut LoopState, block: bool) -> Result<Round> {
        let chunk_tokens = st.chunk_tokens;
        if self.faults.armed {
            self.fault_step(st);
            if self.faults.crashed {
                return self.crashed_round(st, block);
            }
        }
        self.drain_intake(st.sim_now_ns);
        self.release_due(st.sim_now_ns);
        let admitted = self.admit(st);
        let (requeued, shed_round) = self.preempt_scan(st);

        // Prefill steps off the SRPT index (admission order under
        // whole-prompt mode; least-remaining-first under chunking,
        // with the oracle's exact anti-starvation bypass rule).
        let mut prefill_progressed = false;
        while st.staged > 0 {
            let idx = match st.select_prefill() {
                Some(i) => i,
                // The index should always cover the staged set; if it
                // ever desyncs, self-heal from the oracle's linear
                // scan instead of spinning the outer loop.
                None => {
                    debug_assert!(false, "SRPT index lost a staged member");
                    match Self::next_prefill(&st.running, chunk_tokens.is_some()) {
                        Some(i) => {
                            let key = st.srpt_key(&st.running[i]);
                            let seq = st.running[i].seq;
                            st.srpt.push(Reverse((key, seq)));
                            i
                        }
                        None => {
                            st.staged = 0; // counter was stale: no prompt is staged
                            break;
                        }
                    }
                }
            };
            prefill_progressed = true;
            self.prefill_step_at(st, idx, chunk_tokens)?;
            if chunk_tokens.is_some() {
                break;
            }
        }

        if st.running.is_empty() {
            return match self
                .idle_step(st, admitted, requeued, shed_round, prefill_progressed, block)?
            {
                RoundIdle::Continue => Ok(Round::Continue),
                RoundIdle::Finished => Ok(Round::Finished),
                RoundIdle::WouldBlock => Ok(Round::WouldBlock),
            };
        }

        // Real work happened this round: any requeue stall is over.
        st.stalled_requeue_rounds = 0;

        // A chunked policy can leave the whole batch mid-prefill; no
        // decode iteration runs until at least one prompt completes.
        if st.decoding == 0 {
            return Ok(Round::Continue);
        }

        // Decode: fast-forward a uniform lockstep stretch when no
        // admission can change the batch before a membership event —
        // every member is decoding, and either the batch is full or
        // nothing is pending.  (A scheduler holding pending work with
        // free slots is consulted every iteration, exactly like the
        // oracle, because its `next_batch` may admit at any round.)
        let fast = st.decoding == st.running.len()
            && (st.running.len() == self.max_batch || self.scheduler.pending() == 0);
        let horizon = if self.policy.preempt { st.min_horizon() } else { Some(f64::INFINITY) };
        self.decode_round(st, fast, horizon)?;

        // Retire finished requests (same swap-remove order as the
        // oracle's retire scan).
        st.retire_finished();
        Ok(Round::Continue)
    }
}

/// What one scheduling round reported back to its driver (the blocking
/// [`Server::run_to_completion`] loop or a [`ShardRun`] batch).
enum Round {
    /// The round ran (simulated progress, a clock jump, or bounded stall
    /// bookkeeping) — run another.
    Continue,
    /// Every source of work is exhausted: the run is complete.
    Finished,
    /// Non-blocking mode only: nothing can progress until the live intake
    /// delivers a request (see [`RoundIdle::WouldBlock`]).
    WouldBlock,
}

/// Progress verdict of one [`ShardRun::poll`] batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPoll {
    /// The batch ran its full round budget; more work may remain — poll
    /// again.
    Progressed,
    /// The shard cannot progress until its live intake delivers; poll
    /// again later (executor workers back off instead of spinning).
    WouldBlock,
    /// The run is complete: call [`ShardRun::finish`].
    Finished,
}

/// A resumable serving run — the work-stealing executor's unit of
/// scheduling (see [`crate::runtime::executor`]).
///
/// [`Server::run_to_completion`] drives the round loop to the end on one
/// dedicated thread; a `ShardRun` exposes the *same* loop in batches of
/// rounds so a pooled worker can interleave many shards.  Simulated
/// results are identical by construction — the rounds run in the same
/// order over the same state, and nothing in a round observes where the
/// host-thread boundaries fall.  Host wall time accumulates across `poll`
/// calls (time parked in the executor's queues is not charged), and the
/// intake is probed with `try_recv` instead of parking (see
/// [`Server::idle_step`]).
pub struct ShardRun<'a, E: TokenEngine, S: Scheduler, R: Recorder = NopRecorder> {
    server: &'a mut Server<E, S, R>,
    st: Option<LoopState>,
    wall_ns: f64,
    finished: bool,
}

impl<'a, E: TokenEngine, S: Scheduler, R: Recorder> ShardRun<'a, E, S, R> {
    /// Begin a resumable run on `server` (drains the same work sources as
    /// [`Server::run_to_completion`]).
    pub fn new(server: &'a mut Server<E, S, R>) -> Self {
        let st = server.begin_state();
        ShardRun { server, st: Some(st), wall_ns: 0.0, finished: false }
    }

    /// Run up to `rounds` scheduling rounds (at least one) and report how
    /// the batch ended.  Polling after `Finished` is a no-op.
    pub fn poll(&mut self, rounds: u64) -> Result<BatchPoll> {
        if self.finished {
            return Ok(BatchPoll::Finished);
        }
        // `st` is seeded by `new` and only taken by `finish`, which
        // consumes `self`; a bare `None` here means a caller bug.
        let Some(st) = self.st.as_mut() else {
            anyhow::bail!("poll on a consumed ShardRun");
        };
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now(); // detcheck: allow(wall-clock) -- per-poll-batch wall timer ("one timer per poll batch", PR 6); feeds wall_ns only
        let mut verdict = BatchPoll::Progressed;
        for _ in 0..rounds.max(1) {
            match self.server.round(st, false)? {
                Round::Continue => {}
                Round::Finished => {
                    verdict = BatchPoll::Finished;
                    break;
                }
                Round::WouldBlock => {
                    verdict = BatchPoll::WouldBlock;
                    break;
                }
            }
        }
        self.wall_ns += t0.elapsed().as_nanos() as f64;
        if verdict == BatchPoll::Finished {
            self.finished = true;
        }
        Ok(verdict)
    }

    /// Assemble the report once `poll` returned [`BatchPoll::Finished`].
    pub fn finish(mut self) -> ServerReport {
        let st = match self.st.take() {
            Some(st) => st,
            // `new` always seeds `st` and only this method takes it,
            // consuming `self`: the arm cannot execute.
            None => unreachable!("finish on a consumed ShardRun"),
        };
        self.server.finish_report(st, self.wall_ns)
    }
}

/// What an empty-batch round decided (see [`Server::idle_step`]).
enum RoundIdle {
    /// Keep looping: the clock may have jumped, a blocked intake
    /// delivered, or the stall bookkeeping says to drain another round.
    Continue,
    /// Every source of work is exhausted: the run is complete.
    Finished,
    /// Non-blocking mode only: the intake is open but empty — the shard
    /// cannot progress until a live submission arrives.
    WouldBlock,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn server(max_batch: usize) -> Server<SyntheticEngine> {
        Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            max_batch,
        )
    }

    #[test]
    fn serves_all_requests() {
        let mut s = server(2);
        for id in 0..5 {
            s.submit(Request::new(id, vec![id as u32, 7], 6));
        }
        let report = s.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.total_tokens, 30);
        for r in &report.results {
            assert_eq!(r.tokens.len(), 6);
            assert_eq!(r.prompt_tokens, 2);
            assert!(!r.shed);
            assert!(r.sim_ttft_ns > 0.0);
            assert!(r.sim_total_ns > r.sim_ttft_ns);
            assert!(r.sim_finish_at_ns > r.sim_first_token_at_ns);
            assert!(r.e2e_ns() > r.ttft_ns());
        }
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].tokens, 30);
        assert!(report.shards[0].occupancy > 0.0 && report.shards[0].occupancy <= 1.0);
        assert!(report.shards[0].sim_clock_ns > 0.0);
        assert_eq!(report.shards[0].sim_idle_ns, 0.0);
        // Whole-prompt prefill: one prefill step per request, no
        // preemption activity under the default policy.
        assert_eq!(report.shards[0].prefill_chunks, 5);
        assert_eq!(report.shards[0].preemptions, 0);
        assert_eq!(report.shards[0].shed, 0);
    }

    #[test]
    fn generation_is_deterministic() {
        let run = |batch| {
            let mut s = server(batch);
            s.submit(Request::new(0, vec![3, 1, 4], 8));
            s.run_to_completion().unwrap().results[0].tokens.clone()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn longer_prompts_cost_more_simulated_prefill() {
        let mut s = server(1);
        s.submit(Request::new(0, vec![1; 4], 1));
        s.submit(Request::new(1, vec![1; 512], 1));
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results[1].sim_ttft_ns > rep.results[0].sim_ttft_ns);
    }

    #[test]
    fn empty_server_reports_zero() {
        let mut s = server(1);
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.total_tokens, 0);
        assert!(rep.results.is_empty());
        assert_eq!(rep.shards[0].decode_iterations, 0);
        assert_eq!(rep.shards[0].prefill_chunks, 0);
    }

    #[test]
    fn zero_token_requests_retire_at_prefill() {
        let mut s = server(2);
        s.submit(Request::new(0, vec![1, 2], 0));
        s.submit(Request::new(1, vec![3], 0));
        s.submit(Request::new(2, vec![4], 0));
        s.submit(Request::new(3, vec![5, 6], 2));
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 4);
        assert_eq!(rep.total_tokens, 2);
        for r in &rep.results[..3] {
            assert!(r.tokens.is_empty(), "req {} must not decode", r.id);
            assert!(r.sim_ttft_ns > 0.0);
            assert_eq!(r.sim_total_ns, r.sim_ttft_ns);
            assert_eq!(r.sim_finish_at_ns, r.sim_first_token_at_ns);
        }
        assert_eq!(rep.results[3].tokens.len(), 2);
    }

    #[test]
    fn decode_cache_persists_across_runs() {
        let mut s = server(2);
        s.submit(Request::new(0, vec![5, 6], 4));
        s.run_to_completion().unwrap();
        let priced = s.decode_cache_len();
        assert!(priced >= 1, "first run must prime the bucket cache");
        let misses = s.racam().service().misses();

        // Same context buckets again: no new buckets, no new searches.
        s.submit(Request::new(1, vec![9, 2], 4));
        s.run_to_completion().unwrap();
        assert_eq!(s.decode_cache_len(), priced);
        assert_eq!(s.racam().service().misses(), misses);
    }

    #[test]
    fn timed_arrivals_wait_for_the_clock() {
        // A request arriving far in the simulated future is served after
        // the clock jumps, and the gap shows up as idle time.
        let mut s = server(2);
        s.submit(Request::new(0, vec![1, 2], 2));
        let late_arrival = 10_000_000_000_000u64; // way past any service time
        s.submit(Request::new(1, vec![3, 4], 2).at(late_arrival));
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 2);
        let late = &rep.results[1];
        assert_eq!(late.arrival_ns, late_arrival as f64);
        assert!(late.sim_finish_at_ns > late_arrival as f64);
        // TTFT excludes the time before arrival.
        assert!(late.ttft_ns() < late_arrival as f64 / 2.0);
        assert!(rep.shards[0].sim_idle_ns > 0.0, "clock jump must be idle-accounted");
        assert!(rep.shards[0].utilization() < 1.0);
    }

    #[test]
    fn queueing_delay_shows_in_ttft_not_in_intrinsic_prefill() {
        // Two requests, batch 1: the second waits for the first, so its
        // serving TTFT exceeds its intrinsic prefill cost.
        let mut s = server(1);
        s.submit(Request::new(0, vec![1, 2], 4));
        s.submit(Request::new(1, vec![3, 4], 4));
        let rep = s.run_to_completion().unwrap();
        let second = &rep.results[1];
        assert!(second.ttft_ns() > second.sim_ttft_ns * 1.5, "queue wait missing from TTFT");
    }

    #[test]
    fn intake_accepts_requests_mid_run() {
        let mut s = server(2);
        s.submit(Request::new(0, vec![1, 2], 3));
        let tx = s.open_intake();
        #[allow(clippy::disallowed_methods)] // test harness thread
        let worker = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            tx.send(Request::new(7, vec![9, 9], 3)).unwrap();
            // Dropping tx closes the intake and lets the run finish.
        });
        let rep = s.run_to_completion().unwrap();
        worker.join().unwrap();
        assert_eq!(rep.results.len(), 2);
        assert!(rep.results.iter().any(|r| r.id == 7 && r.tokens.len() == 3));
    }

    #[test]
    fn deadline_accounting() {
        let mut s = server(1);
        s.submit(Request::new(0, vec![1], 2).with_deadline(u64::MAX));
        s.submit(Request::new(1, vec![2], 2).with_deadline(1));
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results[0].met_deadline());
        assert!(!rep.results[1].met_deadline());
    }

    #[test]
    fn clamp_compares_against_the_exact_clock() {
        // In-past arrivals truncate to the f64 clock instead of rounding
        // up past it (the old ceil()-clamp parked them up to 1 ns in the
        // future).
        let clamp = |arrival: u64, now: f64| {
            Server::<SyntheticEngine>::clamp_arrival(Request::new(0, vec![1], 1).at(arrival), now)
                .arrival_ns
        };
        assert_eq!(clamp(2, 3.5), 3, "in-past arrival clamps to <= now, not ceil(now)");
        assert_eq!(clamp(3, 3.5), 3, "already in-past by a fraction: clamp down");
        assert_eq!(clamp(4, 3.5), 4, "future arrivals are untouched");
        assert_eq!(clamp(3, 3.0), 3, "arrival exactly at an integer clock is kept");
        assert!((clamp(0, 7.9) as f64) <= 7.9, "clamped arrival is never after the clock");
    }

    /// A deliberately misbehaving scheduler that accepts submissions but
    /// never hands work back — violating the `next_batch` contract.
    struct WithholdingScheduler {
        queue: Vec<Request>,
    }

    impl Scheduler for WithholdingScheduler {
        fn submit(&mut self, req: Request) {
            self.queue.push(req);
        }
        fn pending(&self) -> usize {
            self.queue.len()
        }
        fn next_batch(&mut self, _slots: usize) -> Vec<Request> {
            Vec::new() // withhold everything, forever
        }
    }

    #[test]
    fn withholding_scheduler_is_detected_not_spun_on() {
        // Regression test for the scheduler-contract bail path: a policy
        // that withholds queued work must error out, not hang the loop.
        let mut s = Server::with_scheduler(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
            WithholdingScheduler { queue: Vec::new() },
        );
        s.submit(Request::new(0, vec![1, 2], 4));
        s.submit(Request::new(1, vec![3], 4));
        let err = s.run_to_completion().unwrap_err().to_string();
        assert!(err.contains("withheld 2 queued request(s)"), "unexpected error: {err}");
    }

    /// A scheduler that admits normally but re-queues every running
    /// request unconditionally — the preemption analogue of withholding.
    struct RequeueForeverScheduler {
        inner: FcfsBatcher,
    }

    impl Scheduler for RequeueForeverScheduler {
        fn submit(&mut self, req: Request) {
            self.inner.submit(req);
        }
        fn pending(&self) -> usize {
            Scheduler::pending(&self.inner)
        }
        fn next_batch(&mut self, slots: usize) -> Vec<Request> {
            self.inner.next_batch(slots)
        }
        fn should_preempt(&mut self, _req: &Request, _gen: usize, _now: f64) -> Preemption {
            Preemption::Requeue
        }
    }

    #[test]
    fn requeue_forever_scheduler_is_detected() {
        let mut s = Server::with_scheduler(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
            RequeueForeverScheduler { inner: FcfsBatcher::new(2) },
        );
        s.set_policy(ServingPolicy::whole_prefill().with_preemption());
        s.submit(Request::new(0, vec![1, 2], 4));
        let err = s.run_to_completion().unwrap_err().to_string();
        assert!(err.contains("re-queued"), "unexpected error: {err}");
    }

    #[test]
    fn chunked_prefill_preserves_totals_and_tokens() {
        // Chunking changes the *schedule*, not what is computed: same
        // tokens, same intrinsic prefill cost (chunk costs telescope),
        // more prefill steps.
        let run = |policy: ServingPolicy| {
            let mut s = server(2).with_policy(policy);
            s.submit(Request::new(0, vec![1; 700], 4));
            s.submit(Request::new(1, vec![2; 30], 4));
            s.run_to_completion().unwrap()
        };
        let whole = run(ServingPolicy::whole_prefill());
        let chunked = run(ServingPolicy::chunked(256));
        assert_eq!(whole.results.len(), chunked.results.len());
        for (w, c) in whole.results.iter().zip(&chunked.results) {
            assert_eq!(w.id, c.id);
            assert_eq!(w.tokens, c.tokens, "req {}: chunking must not change generation", w.id);
            let rel = (w.sim_ttft_ns - c.sim_ttft_ns).abs() / w.sim_ttft_ns;
            assert!(rel < 1e-9, "req {}: intrinsic prefill cost must telescope ({rel})", w.id);
        }
        // 700 tokens in 256-token chunks = 3 steps, plus 1 for the short
        // prompt; whole mode takes exactly one step per prompt.
        assert_eq!(whole.shards[0].prefill_chunks, 2);
        assert_eq!(chunked.shards[0].prefill_chunks, 4);
    }

    #[test]
    fn chunked_prefill_bounds_decode_stall() {
        // A short request decoding while a long prompt prefills: under
        // whole-prompt prefill its tokens stall behind the entire prompt;
        // under chunked prefill, decode iterations interleave between
        // chunks, so the short request finishes earlier on the clock.
        let run = |policy: ServingPolicy| {
            let mut s = server(2).with_policy(policy);
            // Short request first: it is decoding by the time the long
            // prompt is admitted.
            s.submit(Request::new(0, vec![1; 4], 8));
            s.submit(Request::new(1, vec![2; 2000], 2).at(1));
            s.run_to_completion().unwrap()
        };
        let whole = run(ServingPolicy::whole_prefill());
        let chunked = run(ServingPolicy::chunked(256));
        let short_whole = &whole.results[0];
        let short_chunked = &chunked.results[0];
        assert!(
            short_chunked.sim_finish_at_ns < short_whole.sim_finish_at_ns,
            "chunked: short request must finish earlier ({} vs {})",
            short_chunked.sim_finish_at_ns,
            short_whole.sim_finish_at_ns
        );
        // The stall a decoder suffered per prefill step is bounded by one
        // chunk, so total chunk-stall time shrinks... but is still > 0.
        assert!(chunked.shards[0].chunk_stall_ns > 0.0);
        assert!(whole.shards[0].chunk_stall_ns > chunked.shards[0].chunk_stall_ns);
    }

    #[test]
    fn chunked_prefill_improves_short_request_ttft() {
        // A long and a short prompt admitted together (FCFS order puts
        // the long one first): under whole-prompt prefill the short's
        // first token waits behind the entire long prefill; under chunked
        // prefill, shortest-remaining-first completes the short's single
        // chunk immediately and it decodes while the long prompt chunks.
        let run = |policy: ServingPolicy| {
            let mut s = server(2).with_policy(policy);
            s.submit(Request::new(0, vec![1; 2048], 2));
            s.submit(Request::new(1, vec![2; 32], 2));
            s.run_to_completion().unwrap()
        };
        let whole = run(ServingPolicy::whole_prefill());
        let chunked = run(ServingPolicy::chunked(256));
        let ttft = |rep: &ServerReport| rep.results.iter().find(|r| r.id == 1).unwrap().ttft_ns();
        let (short_w, short_c) = (ttft(&whole), ttft(&chunked));
        assert!(
            short_c < short_w * 0.5,
            "chunked short TTFT {short_c} must undercut whole-prefill {short_w}"
        );
        // The long prompt still completes with identical tokens.
        assert_eq!(whole.results[0].tokens, chunked.results[0].tokens);
    }

    #[test]
    fn chunked_prefill_never_starves_a_long_prompt() {
        // Chunked mode prefers the shortest remaining prefill, but a
        // sustained stream of short arrivals must not starve a long
        // prompt: after MAX_PREFILL_BYPASSES consecutive bypasses it gets
        // a chunk, so it finishes well before the short stream drains.
        let mut s = server(2).with_policy(ServingPolicy::chunked(64));
        s.submit(Request::new(0, vec![1; 512], 1)); // 8 chunks of 64
        for id in 1..=60 {
            s.submit(Request::new(id, vec![2; 32], 1));
        }
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 61);
        let long = &rep.results[0];
        let last_short_finish =
            rep.results[1..].iter().map(|r| r.sim_finish_at_ns).fold(0.0f64, f64::max);
        assert!(
            long.sim_finish_at_ns < last_short_finish,
            "long prompt starved: finished at {} vs last short at {}",
            long.sim_finish_at_ns,
            last_short_finish
        );
    }

    #[test]
    fn edf_preemption_sheds_past_deadline_work() {
        use crate::coordinator::scheduler::EdfScheduler;
        let mk = |policy: ServingPolicy| {
            let mut s = Server::with_scheduler(
                SyntheticEngine::new(64, 128),
                RacamSystem::new(&racam_paper()),
                tiny_spec(),
                1,
                EdfScheduler::new(),
            );
            s.set_policy(policy);
            // Request 0 occupies the single slot for a long time; request
            // 1's deadline expires while it waits in the queue.
            s.submit(Request::new(0, vec![1; 64], 64).with_deadline(u64::MAX));
            s.submit(Request::new(1, vec![2; 64], 64).with_deadline(1));
            s.run_to_completion().unwrap()
        };
        // Without preemption both run to completion (one just misses).
        let kept = mk(ServingPolicy::whole_prefill());
        assert_eq!(kept.shards[0].shed, 0);
        assert_eq!(kept.results.iter().filter(|r| !r.met_deadline()).count(), 1);
        assert_eq!(kept.total_tokens, 128);

        // With preemption the dead request is shed after at most one
        // decode iteration and the survivor still completes.
        let shed = mk(ServingPolicy::whole_prefill().with_preemption());
        assert_eq!(shed.shards[0].shed, 1);
        let r1 = shed.results.iter().find(|r| r.id == 1).unwrap();
        assert!(r1.shed);
        assert!(!r1.met_deadline());
        assert!(r1.tokens.len() < 64, "shed request must not run to completion");
        let r0 = shed.results.iter().find(|r| r.id == 0).unwrap();
        assert!(!r0.shed);
        assert_eq!(r0.tokens.len(), 64);
        assert!(shed.total_tokens < kept.total_tokens);
    }

    #[test]
    fn policy_accessors_roundtrip() {
        let s = server(1).with_policy(ServingPolicy::interactive());
        assert_eq!(s.policy(), ServingPolicy::interactive());
    }

    #[test]
    fn prefill_role_hands_prompts_off_instead_of_decoding() {
        let mut s = server(2);
        s.set_role(ShardRole::Prefill);
        s.set_group("prefill");
        s.submit(Request::new(0, vec![1; 32], 4));
        s.submit(Request::new(1, vec![2; 16], 0)); // nothing to decode
        let rep = s.run_to_completion().unwrap();
        // The zero-token request completes here; the other leaves as a
        // handoff and produces no result on this shard.
        assert_eq!(rep.results.len(), 1);
        assert_eq!(rep.results[0].id, 1);
        assert_eq!(rep.total_tokens, 0);
        assert_eq!(rep.shards[0].requests, 2, "handoffs count as retired work");
        assert_eq!(rep.shards[0].handoffs, 1);
        assert_eq!(rep.shards[0].decode_iterations, 0);
        assert_eq!(rep.shards[0].group, "prefill");
        assert_eq!(rep.shards[0].role, ShardRole::Prefill);
        let handoffs = s.take_handoffs();
        assert_eq!(handoffs.len(), 1);
        let h = &handoffs[0];
        assert_eq!(h.req.id, 0);
        assert_eq!(h.req.max_new_tokens, 4);
        assert!(h.sim_prefill_ns > 0.0);
        assert!(h.prefill_finish_at_ns >= h.sim_prefill_ns);
        // Taking drains the queue.
        assert!(s.take_handoffs().is_empty());
    }

    /// Re-queues every running request exactly once, then keeps it.
    struct RequeueOnceScheduler {
        inner: FcfsBatcher,
        requeued: std::collections::HashSet<u64>,
    }

    impl Scheduler for RequeueOnceScheduler {
        fn submit(&mut self, req: Request) {
            self.inner.submit(req);
        }
        fn pending(&self) -> usize {
            Scheduler::pending(&self.inner)
        }
        fn next_batch(&mut self, slots: usize) -> Vec<Request> {
            self.inner.next_batch(slots)
        }
        fn should_preempt(&mut self, req: &Request, _gen: usize, _now: f64) -> Preemption {
            if self.requeued.insert(req.id) {
                Preemption::Requeue
            } else {
                Preemption::Keep
            }
        }
    }

    #[test]
    fn requeued_handoff_keeps_its_bookkeeping_and_never_reprefills() {
        // Regression: a scheduler re-queue on a decode shard must not turn
        // a received handoff back into a fresh prompt — the KV cache is
        // resident, so re-admission skips prefill, the result keeps the
        // original arrival and prefill cost, and the link is charged once.
        let mut pre = server(1);
        pre.set_role(ShardRole::Prefill);
        pre.submit(Request::new(0, vec![3; 24], 4));
        pre.run_to_completion().unwrap();
        let handoff = pre.take_handoffs().pop().expect("one handoff");
        let prefill_cost = handoff.sim_prefill_ns;

        let mut dec = Server::with_scheduler(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            1,
            RequeueOnceScheduler {
                inner: FcfsBatcher::new(1),
                requeued: std::collections::HashSet::new(),
            },
        );
        dec.set_role(ShardRole::Decode);
        dec.set_policy(ServingPolicy::whole_prefill().with_preemption());
        let kv_ns = 500.0;
        dec.submit_handoff(handoff, kv_ns);
        let rep = dec.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 1);
        let r = &rep.results[0];
        assert!(!r.shed);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.arrival_ns, 0.0, "original arrival must survive the re-queue");
        assert_eq!(r.sim_ttft_ns, prefill_cost, "carried prefill cost must survive");
        assert_eq!(rep.shards[0].preemptions, 1);
        assert_eq!(rep.shards[0].prefill_chunks, 0, "decode shard must never re-prefill");
        assert_eq!(rep.shards[0].handoffs, 1, "one link crossing, counted once");
        assert_eq!(rep.shards[0].kv_transfer_ns, kv_ns, "transfer charged once");
    }

    /// Assert two reports agree on every *simulated* quantity bit-for-bit
    /// (host wall-clock fields are nondeterministic by nature and differ
    /// even between two runs of the same engine).  One comparator —
    /// [`ServerReport::sim_divergence`] — backs every equivalence gate.
    fn assert_reports_identical(a: &ServerReport, b: &ServerReport) {
        if let Some(d) = a.sim_divergence(b) {
            panic!("reports diverged: {d}");
        }
    }

    #[test]
    fn calendar_matches_oracle_bit_for_bit() {
        // Mixed workload exercising every fast-forward boundary: timed
        // arrivals, prompts spanning several pricing buckets, token
        // budgets that retire members mid-run, and a queue deeper than
        // the batch.
        let run = |engine: crate::config::EngineKind| {
            let mut s = server(3).with_policy(ServingPolicy::whole_prefill().with_engine(engine));
            s.submit(Request::new(0, vec![1; 300], 40));
            s.submit(Request::new(1, vec![2; 4], 700)); // crosses decode buckets
            s.submit(Request::new(2, vec![3; 600], 12).at(1_000));
            s.submit(Request::new(3, vec![4; 32], 5).at(50_000_000_000));
            for id in 4..10 {
                s.submit(Request::new(id, vec![id as u32; 16], 9));
            }
            s.run_to_completion().unwrap()
        };
        let cal = run(crate::config::EngineKind::Calendar);
        let ora = run(crate::config::EngineKind::Oracle);
        assert_reports_identical(&cal, &ora);
    }

    #[test]
    fn calendar_matches_oracle_under_chunking_and_preemption() {
        use crate::coordinator::scheduler::EdfScheduler;
        let run = |policy: ServingPolicy, deadline: u64| {
            let mut s = Server::with_scheduler(
                SyntheticEngine::new(64, 128),
                RacamSystem::new(&racam_paper()),
                tiny_spec(),
                2,
                EdfScheduler::new(),
            );
            s.set_policy(policy);
            s.submit(Request::new(0, vec![1; 900], 30).with_deadline(u64::MAX));
            s.submit(Request::new(1, vec![2; 64], 200).with_deadline(deadline));
            s.submit(Request::new(2, vec![3; 16], 6).at(10_000));
            s.run_to_completion().unwrap()
        };
        // Probe (no preemption) to place request 1's deadline squarely
        // between its first token and its completion: in the preempting
        // runs the timeline is identical up to the shed, so the EDF shed
        // is guaranteed to fire *mid-stretch*, and both engines must fire
        // it at the same simulated iteration.
        let probe = run(ServingPolicy::chunked(128), u64::MAX);
        let r1 = probe.results.iter().find(|r| r.id == 1).unwrap();
        let mid = ((r1.sim_first_token_at_ns + r1.sim_finish_at_ns) / 2.0) as u64;
        let base = ServingPolicy::chunked(128).with_preemption();
        let cal = run(base, mid);
        let ora = run(base.oracle(), mid);
        assert_reports_identical(&cal, &ora);
        assert_eq!(cal.shards[0].shed, 1, "the dead request must be shed mid-stretch");
        let shed = cal.results.iter().find(|r| r.id == 1).unwrap();
        assert!(shed.shed);
        assert!(
            !shed.tokens.is_empty() && shed.tokens.len() < 200,
            "shed mid-decode: got {} tokens",
            shed.tokens.len()
        );
    }

    #[test]
    fn calendar_prices_the_same_buckets_as_the_oracle() {
        // The precomputed bucket schedule must not change what gets
        // priced: same decode-cache population, same mapping-service
        // miss/hit counters.
        let run = |engine: crate::config::EngineKind| {
            let mut s = server(2).with_policy(ServingPolicy::whole_prefill().with_engine(engine));
            s.submit(Request::new(0, vec![1; 100], 400)); // crosses bucket edges
            s.submit(Request::new(1, vec![2; 300], 8));
            let rep = s.run_to_completion().unwrap();
            (rep, s.decode_cache_len(), s.racam().service().misses(), s.racam().service().hits())
        };
        let (cal, cal_buckets, cal_misses, cal_hits) = run(crate::config::EngineKind::Calendar);
        let (ora, ora_buckets, ora_misses, ora_hits) = run(crate::config::EngineKind::Oracle);
        assert_reports_identical(&cal, &ora);
        assert_eq!(cal_buckets, ora_buckets, "same decode buckets priced");
        assert_eq!(cal_misses, ora_misses, "same unique kernel shapes searched");
        assert_eq!(cal_hits, ora_hits, "same cache-served pricing requests");
    }

    #[test]
    fn withholding_scheduler_is_detected_by_the_calendar_engine_too() {
        let mut s = Server::with_scheduler(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
            WithholdingScheduler { queue: Vec::new() },
        );
        s.submit(Request::new(0, vec![1, 2], 4));
        let err = s.run_to_completion().unwrap_err().to_string();
        assert!(err.contains("withheld 1 queued request(s)"), "unexpected error: {err}");
    }

    #[test]
    fn decode_shard_admits_handoffs_without_reprefilling() {
        // Run the same request (a) unified and (b) prefill shard → decode
        // shard: the tokens must match, the decode shard must charge no
        // prefill steps, and the handoff's accounting must carry over.
        let unified = {
            let mut s = server(1);
            s.submit(Request::new(0, vec![3; 24], 5));
            s.run_to_completion().unwrap()
        };

        let mut pre = server(1);
        pre.set_role(ShardRole::Prefill);
        pre.submit(Request::new(0, vec![3; 24], 5));
        pre.run_to_completion().unwrap();
        let handoff = pre.take_handoffs().pop().expect("one handoff");
        let prefill_cost = handoff.sim_prefill_ns;
        let finish = handoff.prefill_finish_at_ns;

        let mut dec = server(1);
        dec.set_role(ShardRole::Decode);
        let kv_ns = 1_000.0;
        dec.submit_handoff(handoff, kv_ns);
        let rep = dec.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 1);
        let r = &rep.results[0];
        assert_eq!(r.tokens, unified.results[0].tokens, "handoff must not change generation");
        assert_eq!(rep.shards[0].prefill_chunks, 0, "decode shard never prefills a handoff");
        assert_eq!(rep.shards[0].handoffs, 1);
        assert_eq!(rep.shards[0].kv_transfer_ns, kv_ns);
        // Intrinsic TTFT carries the prefill shard's cost; the decode
        // clock released the request only after prefill + transfer (the
        // gap shows up as idle time on the decode shard).
        assert_eq!(r.sim_ttft_ns, prefill_cost);
        assert!(r.sim_total_ns > prefill_cost);
        assert!(r.sim_first_token_at_ns >= finish + kv_ns - 1.0);
        assert!(rep.shards[0].sim_idle_ns > 0.0);
        // Original arrival is preserved for end-to-end latency.
        assert_eq!(r.arrival_ns, 0.0);
        assert!(r.ttft_ns() >= finish + kv_ns - 1.0);
    }

    #[test]
    fn crash_at_zero_evacuates_everything_untouched() {
        let mut s = server(2);
        s.fault_crash_at(0.0);
        for id in 0..3 {
            s.submit(Request::new(id, vec![id as u32, 7], 6).at(id * 10));
        }
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results.is_empty(), "a shard dead at t=0 serves nothing");
        assert!(s.fault_crashed());
        assert_eq!(s.crash_detected_at(), 0.0);
        let mut evac = s.take_evacuated();
        evac.sort_by_key(|r| r.id);
        let got: Vec<(u64, u64)> = evac.iter().map(|r| (r.id, r.arrival_ns)).collect();
        // Queued and future requests come back whole with their original
        // arrivals — nothing is lost or rewritten by the evacuation.
        assert_eq!(got, vec![(0, 0), (1, 10), (2, 20)]);
        // The buffer drains exactly once.
        assert!(s.take_evacuated().is_empty());
    }

    #[test]
    fn mid_run_crash_is_engine_identical() {
        use crate::config::EngineKind;
        let run = |engine: EngineKind, crash_at: Option<f64>| {
            let mut s = server(2).with_policy(ServingPolicy::whole_prefill().with_engine(engine));
            if let Some(at) = crash_at {
                s.fault_crash_at(at);
            }
            for id in 0..6 {
                s.submit(Request::new(id, vec![id as u32, 7], 6));
            }
            let rep = s.run_to_completion().unwrap();
            let mut evac = s.take_evacuated();
            evac.sort_by_key(|r| r.id);
            let detect = if s.fault_crashed() { s.crash_detected_at() } else { -1.0 };
            (rep, evac.iter().map(|r| r.id).collect::<Vec<_>>(), detect)
        };
        // Crash halfway through the fault-free makespan: some requests
        // complete, the rest evacuate — detection time, evacuee set, and
        // completed results must match across engines bit-for-bit.
        let (base, _, _) = run(EngineKind::Calendar, None);
        let at = base.shards[0].sim_clock_ns / 2.0;
        let (cal, cal_evac, cal_detect) = run(EngineKind::Calendar, Some(at));
        let (ora, ora_evac, ora_detect) = run(EngineKind::Oracle, Some(at));
        assert!(!cal_evac.is_empty(), "the crash must catch some requests in flight");
        assert!(cal.results.len() < 6);
        assert_eq!(cal_evac, ora_evac);
        assert_eq!(cal_detect.to_bits(), ora_detect.to_bits());
        assert_eq!(cal.sim_divergence(&ora), None);
    }

    #[test]
    fn brownout_slows_both_engines_identically() {
        use crate::config::EngineKind;
        let run = |engine: EngineKind, slow: bool| {
            let mut s = server(2).with_policy(ServingPolicy::whole_prefill().with_engine(engine));
            if slow {
                s.fault_brownout(0.0, 1e15, 3.0);
            }
            for id in 0..4 {
                s.submit(Request::new(id, vec![id as u32, 7], 6));
            }
            s.run_to_completion().unwrap()
        };
        let cal = run(EngineKind::Calendar, true);
        let ora = run(EngineKind::Oracle, true);
        assert_eq!(cal.sim_divergence(&ora), None);
        let base = run(EngineKind::Calendar, false);
        assert!(
            cal.shards[0].sim_clock_ns > base.shards[0].sim_clock_ns,
            "a 3x brownout over the whole run must stretch the makespan"
        );
        // Tokens are untouched: a brownout reprices, it never regenerates.
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&cal), tok(&base));
    }

    #[test]
    fn bounded_brownout_window_only_charges_inside_it() {
        use crate::config::EngineKind;
        // A brownout that ends before the run starts moving changes
        // nothing; parity must hold with a window edge mid-run too.
        let run = |engine: EngineKind, window: (f64, f64)| {
            let mut s = server(2).with_policy(ServingPolicy::whole_prefill().with_engine(engine));
            s.fault_brownout(window.0, window.1, 2.0);
            for id in 0..4 {
                s.submit(Request::new(id, vec![id as u32, 7], 6));
            }
            s.run_to_completion().unwrap()
        };
        let base = {
            let mut s = server(2);
            for id in 0..4 {
                s.submit(Request::new(id, vec![id as u32, 7], 6));
            }
            s.run_to_completion().unwrap()
        };
        let mid = base.shards[0].sim_clock_ns / 2.0;
        let cal = run(EngineKind::Calendar, (mid, 1e15));
        let ora = run(EngineKind::Oracle, (mid, 1e15));
        assert_eq!(cal.sim_divergence(&ora), None);
        assert!(cal.shards[0].sim_clock_ns > base.shards[0].sim_clock_ns);
    }
}
