//! The serving loop: continuous-batched greedy decoding through a token
//! engine, with per-token RACAM latency accounting from the shared mapping
//! service (the simulated-hardware clock) next to the host wall clock.
//!
//! A [`Server`] is one worker shard: it owns a token engine, a
//! [`RacamSystem`] handle (typically sharing its [`MappingService`] with
//! every other shard — see [`super::Coordinator`]), a pluggable admission
//! [`Scheduler`] (FCFS by default), and persistent per-bucket prefill and
//! decode cost caches so repeated runs never re-price a bucket.
//!
//! ## The simulated clock and open-loop traffic
//!
//! Each run drives a per-shard simulated clock forward: admitting a
//! request charges its (bucketed) prefill cost, and each decode iteration
//! charges the slowest batch member's per-token cost (the batch steps in
//! lockstep).  Requests carry an [`Request::arrival_ns`] on that clock —
//! a request is invisible to the [`Scheduler`] until the clock reaches its
//! arrival, which is how the open-loop streams of [`crate::traffic`]
//! replay: queueing delay emerges from load instead of being assumed.
//! When the shard is idle and work is pending in the future, the clock
//! jumps to the next arrival and the gap is accounted as idle time
//! ([`ShardStats::sim_idle_ns`]).
//!
//! ## Async admission
//!
//! [`Server::open_intake`] (and [`super::Coordinator::intake`]) return an
//! mpsc sender; requests sent on it are admitted *mid-run*: the serving
//! loop drains the channel between decode iterations, and blocks on it
//! when it would otherwise go idle.  `run_to_completion` returns once all
//! queued work is done **and** every intake sender has been dropped.
//!
//! [`MappingService`]: crate::mapping::MappingService

use super::batcher::{ctx_bucket, FcfsBatcher};
use super::engine::TokenEngine;
use super::scheduler::Scheduler;
use crate::config::LlmSpec;
use crate::metrics::LatencyBreakdown;
use crate::workloads::{decode_kernels, prefill_kernels, stage_latency, RacamSystem};
use crate::Result;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc;
use std::time::Instant;

/// An inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Arrival time on the shard's simulated clock, ns.  Zero (the
    /// default) means "present before the run starts"; a positive value
    /// hides the request from the scheduler until the clock reaches it.
    pub arrival_ns: u64,
    /// Optional end-to-end completion deadline on the simulated clock, ns
    /// (absolute, not relative to arrival).  Consumed by deadline-aware
    /// schedulers and the SLO goodput accounting in [`crate::traffic::slo`].
    pub deadline_ns: Option<u64>,
}

impl Request {
    /// A request available at clock start with no deadline.
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request { id, prompt, max_new_tokens, arrival_ns: 0, deadline_ns: None }
    }

    /// Set the simulated arrival time (open-loop traffic).
    pub fn at(mut self, arrival_ns: u64) -> Self {
        self.arrival_ns = arrival_ns;
        self
    }

    /// Set an absolute completion deadline on the simulated clock.
    pub fn with_deadline(mut self, deadline_ns: u64) -> Self {
        self.deadline_ns = Some(deadline_ns);
        self
    }
}

/// Completed request with its generation and accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Simulated RACAM time to first token (prefill cost alone, excluding
    /// queueing), ns.
    pub sim_ttft_ns: f64,
    /// Simulated RACAM service time attributed to this request (prefill +
    /// its own per-token decode costs), ns.
    pub sim_total_ns: f64,
    /// Host wall-clock spent executing this request's share, ns.
    pub wall_ns: f64,
    /// Arrival time on the shard's simulated clock, ns.
    pub arrival_ns: f64,
    /// Absolute simulated-clock time the first token was ready (includes
    /// queueing delay; `- arrival_ns` is the serving-level TTFT).
    pub sim_first_token_at_ns: f64,
    /// Absolute simulated-clock completion time.
    pub sim_finish_at_ns: f64,
    /// Echo of the request's deadline, for goodput accounting.
    pub deadline_ns: Option<f64>,
}

impl RequestResult {
    /// Serving-level time-to-first-token: queueing delay + prefill.
    pub fn ttft_ns(&self) -> f64 {
        self.sim_first_token_at_ns - self.arrival_ns
    }

    /// Serving-level end-to-end latency (arrival to completion).
    pub fn e2e_ns(&self) -> f64 {
        self.sim_finish_at_ns - self.arrival_ns
    }

    /// Mean time per output token after the first.
    pub fn tpot_ns(&self) -> f64 {
        if self.tokens.len() < 2 {
            return 0.0;
        }
        (self.sim_finish_at_ns - self.sim_first_token_at_ns) / (self.tokens.len() - 1) as f64
    }

    /// Whether this request met its deadline (no deadline counts as met).
    pub fn met_deadline(&self) -> bool {
        self.deadline_ns.map_or(true, |d| self.sim_finish_at_ns <= d)
    }
}

/// Per-shard utilization accounting (one entry per worker).
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests this shard completed.
    pub requests: usize,
    /// Tokens this shard generated.
    pub tokens: usize,
    /// Summed simulated RACAM service time of this shard's requests, ns.
    pub sim_ns: f64,
    /// Host wall-clock of this shard's serving loop, ns.
    pub wall_ns: f64,
    /// Final value of this shard's simulated clock (its makespan), ns.
    pub sim_clock_ns: f64,
    /// Simulated time this shard sat idle waiting for arrivals, ns.
    pub sim_idle_ns: f64,
    /// Decode iterations executed.
    pub decode_iterations: usize,
    /// Mean fraction of batch slots occupied across decode iterations
    /// (1.0 = the shard decoded at full batch the whole run).
    pub occupancy: f64,
}

impl ShardStats {
    /// Fraction of the shard's simulated makespan spent serving (vs idle).
    pub fn utilization(&self) -> f64 {
        if self.sim_clock_ns <= 0.0 {
            return 0.0;
        }
        (self.sim_clock_ns - self.sim_idle_ns) / self.sim_clock_ns
    }
}

/// Aggregate serving report (single shard or merged across shards).
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub results: Vec<RequestResult>,
    pub sim_tokens_per_s: f64,
    pub wall_tokens_per_s: f64,
    pub total_tokens: usize,
    /// Per-shard utilization; one entry for a plain [`Server`] run, one per
    /// worker for a [`super::Coordinator`] run.
    pub shards: Vec<ShardStats>,
}

impl ServerReport {
    /// Merge per-shard reports into one, re-sorting results by request id.
    /// Shards run concurrently, so both clocks use the makespan — the
    /// slowest shard — rather than a sum: `wall_ns` is the
    /// coordinator-level wall clock, and simulated throughput divides by
    /// the largest per-shard simulated clock.
    pub fn merge(reports: Vec<ServerReport>, wall_ns: f64) -> ServerReport {
        let mut results: Vec<RequestResult> = Vec::new();
        let mut shards: Vec<ShardStats> = Vec::new();
        for r in reports {
            results.extend(r.results);
            shards.extend(r.shards);
        }
        results.sort_by_key(|r| r.id);
        shards.sort_by_key(|s| s.shard);
        let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let sim_makespan_ns = shards
            .iter()
            .map(|s| if s.sim_clock_ns > 0.0 { s.sim_clock_ns } else { s.sim_ns })
            .fold(0.0f64, f64::max);
        ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_makespan_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results,
            shards,
        }
    }
}

/// Future-arrival queue entry: min-heap by (arrival, id) for determinism.
#[derive(Debug, PartialEq, Eq)]
struct FutureReq {
    arrival_ns: u64,
    id: u64,
    req: Request,
}

impl Ord for FutureReq {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.arrival_ns, self.id).cmp(&(other.arrival_ns, other.id))
    }
}

impl PartialOrd for FutureReq {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One serving worker (see module docs).
pub struct Server<E: TokenEngine, S: Scheduler = FcfsBatcher> {
    engine: E,
    racam: RacamSystem,
    spec: LlmSpec,
    scheduler: S,
    max_batch: usize,
    shard_id: usize,
    /// Requests whose simulated arrival time has not been reached yet.
    future: BinaryHeap<Reverse<FutureReq>>,
    /// Live intake: requests sent here are admitted mid-run.
    intake: Option<mpsc::Receiver<Request>>,
    /// Simulated per-token decode cost per context bucket, kept across
    /// runs so repeated runs (and long-lived shards) reuse priced buckets.
    decode_cache: HashMap<u64, LatencyBreakdown>,
    /// Simulated prefill cost per prompt-length bucket (same granularity
    /// as the decode cache), so live traffic with many distinct prompt
    /// lengths prices a bounded number of prefill shapes.
    prefill_cache: HashMap<u64, LatencyBreakdown>,
}

struct Running {
    req: Request,
    hidden: Vec<f32>,
    tokens: Vec<u32>,
    sim_ns: f64,
    sim_ttft_ns: f64,
    wall_ns: f64,
    arrival_ns: f64,
    first_token_at_ns: f64,
}

impl<E: TokenEngine> Server<E, FcfsBatcher> {
    /// `spec` names the LLM whose kernel shapes the RACAM clock prices
    /// (the toy engine generates real tokens; the simulator accounts what
    /// the full-size model would cost on RACAM hardware).
    pub fn new(engine: E, racam: RacamSystem, spec: LlmSpec, max_batch: usize) -> Self {
        let scheduler = FcfsBatcher::new(max_batch);
        Server::with_scheduler(engine, racam, spec, max_batch, scheduler)
    }
}

impl<E: TokenEngine, S: Scheduler> Server<E, S> {
    /// A server with an explicit admission policy.
    pub fn with_scheduler(
        engine: E,
        racam: RacamSystem,
        spec: LlmSpec,
        max_batch: usize,
        scheduler: S,
    ) -> Self {
        assert!(max_batch >= 1);
        Server {
            engine,
            racam,
            spec,
            scheduler,
            max_batch,
            shard_id: 0,
            future: BinaryHeap::new(),
            intake: None,
            decode_cache: HashMap::new(),
            prefill_cache: HashMap::new(),
        }
    }

    /// Queue a request.  Requests with a positive [`Request::arrival_ns`]
    /// stay invisible to the scheduler until the simulated clock reaches
    /// their arrival.
    pub fn submit(&mut self, req: Request) {
        if req.arrival_ns > 0 {
            self.future.push(Reverse(FutureReq { arrival_ns: req.arrival_ns, id: req.id, req }));
        } else {
            self.scheduler.submit(req);
        }
    }

    /// Open (or replace) the live intake channel and return its sender.
    /// While any sender is alive, `run_to_completion` keeps serving —
    /// blocking when idle — and only returns after the last sender drops.
    pub fn open_intake(&mut self) -> mpsc::Sender<Request> {
        let (tx, rx) = mpsc::channel();
        self.intake = Some(rx);
        tx
    }

    /// Requests waiting for admission (queued now or arriving later).
    pub fn pending(&self) -> usize {
        self.scheduler.pending() + self.future.len()
    }

    /// Access the simulated-hardware pipeline (e.g. to persist its mapping
    /// cache after a run, §7 amortization).
    pub fn racam(&self) -> &RacamSystem {
        &self.racam
    }

    /// Priced decode context buckets held in server state.
    pub fn decode_cache_len(&self) -> usize {
        self.decode_cache.len()
    }

    /// Label this worker for per-shard reporting (set by the coordinator).
    pub(crate) fn set_shard(&mut self, id: usize) {
        self.shard_id = id;
    }

    /// Simulated prefill cost for a prompt length.  The kernel *shape* is
    /// priced once per [`ctx_bucket`] so live traffic with arbitrary
    /// prompt lengths triggers a bounded number of mapping searches; the
    /// bucket cost is then scaled linearly to the actual token count so
    /// short prompts are not charged a whole bucket's prefill (attention's
    /// quadratic share makes this a mild overestimate below the boundary,
    /// never the ~bucket/len inflation of charging the ceiling).
    fn prefill_cost(&mut self, prompt_len: u64) -> Result<LatencyBreakdown> {
        let len = prompt_len.max(1);
        let bucket = ctx_bucket(len);
        let per_bucket = if let Some(c) = self.prefill_cache.get(&bucket) {
            *c
        } else {
            let cost = stage_latency(&self.racam, &prefill_kernels(&self.spec, bucket))?;
            self.prefill_cache.insert(bucket, cost);
            cost
        };
        Ok(per_bucket.scaled(len as f64 / bucket as f64))
    }

    /// Simulated per-token decode cost at a context length, priced once
    /// per bucket.
    fn decode_cost(&mut self, ctx: u64) -> Result<LatencyBreakdown> {
        let bucket = ctx_bucket(ctx);
        if let Some(c) = self.decode_cache.get(&bucket) {
            return Ok(*c);
        }
        let cost = stage_latency(&self.racam, &decode_kernels(&self.spec, bucket))?;
        self.decode_cache.insert(bucket, cost);
        Ok(cost)
    }

    /// Drain everything currently available on the intake channel without
    /// blocking.  Live submissions arriving "in the past" of the simulated
    /// clock are clamped to now — they arrive when received.
    fn drain_intake(&mut self, sim_now_ns: f64) {
        // Take the receiver out so `submit` can borrow self mutably.
        let Some(rx) = self.intake.take() else { return };
        let mut open = true;
        loop {
            match rx.try_recv() {
                Ok(req) => self.submit(Self::clamp_arrival(req, sim_now_ns)),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if open {
            self.intake = Some(rx);
        }
    }

    fn clamp_arrival(mut req: Request, sim_now_ns: f64) -> Request {
        let now = sim_now_ns.ceil() as u64;
        if req.arrival_ns < now {
            req.arrival_ns = now;
        }
        req
    }

    /// Move future requests whose arrival time has come into the scheduler.
    fn release_due(&mut self, sim_now_ns: f64) {
        while self.future.peek().is_some_and(|r| r.0.arrival_ns as f64 <= sim_now_ns) {
            let Reverse(f) = self.future.pop().expect("peeked entry");
            self.scheduler.submit(f.req);
        }
    }

    /// Drain all submitted requests to completion; with an open intake,
    /// keep serving live submissions until every sender is dropped.
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        let mut running: Vec<Running> = Vec::new();
        let mut done: Vec<RequestResult> = Vec::new();
        let wall_start = Instant::now();
        let mut decode_iterations = 0usize;
        let mut occupancy_sum = 0.0f64;
        let mut sim_now_ns = 0.0f64;
        let mut sim_idle_ns = 0.0f64;

        loop {
            self.drain_intake(sim_now_ns);
            self.release_due(sim_now_ns);

            // Admit new work (continuous batching).  Prefill serializes on
            // the shard: admitting a request advances the simulated clock
            // by its (bucketed) prefill cost.
            let slots = self.max_batch.saturating_sub(running.len());
            let mut admitted = 0usize;
            for req in self.scheduler.next_batch(slots) {
                admitted += 1;
                let t0 = Instant::now();
                let hidden = self.engine.embed_prompt(&req.prompt);
                let prefill = self.prefill_cost(req.prompt.len() as u64)?;
                sim_now_ns += prefill.total_ns();
                if req.max_new_tokens == 0 {
                    // Nothing to decode: retire immediately (prefill-only).
                    done.push(RequestResult {
                        id: req.id,
                        tokens: Vec::new(),
                        sim_ttft_ns: prefill.total_ns(),
                        sim_total_ns: prefill.total_ns(),
                        wall_ns: t0.elapsed().as_nanos() as f64,
                        arrival_ns: req.arrival_ns as f64,
                        sim_first_token_at_ns: sim_now_ns,
                        sim_finish_at_ns: sim_now_ns,
                        deadline_ns: req.deadline_ns.map(|d| d as f64),
                    });
                    continue;
                }
                running.push(Running {
                    hidden,
                    tokens: Vec::new(),
                    sim_ns: prefill.total_ns(),
                    sim_ttft_ns: prefill.total_ns(),
                    wall_ns: t0.elapsed().as_nanos() as f64,
                    arrival_ns: req.arrival_ns as f64,
                    first_token_at_ns: sim_now_ns,
                    req,
                });
            }
            if running.is_empty() {
                if self.scheduler.pending() > 0 {
                    if admitted == 0 {
                        // The scheduler returned nothing while work is
                        // queued and every batch slot is free: that
                        // violates the `Scheduler::next_batch` contract
                        // and would spin this loop forever.
                        anyhow::bail!(
                            "scheduler withheld {} queued request(s) with {} free slots",
                            self.scheduler.pending(),
                            self.max_batch
                        );
                    }
                    // Everything admitted this round retired at prefill
                    // (zero-token requests); keep draining the queue.
                    continue;
                }
                if let Some(r) = self.future.peek() {
                    // Idle until the next arrival: jump the clock.
                    let next = r.0.arrival_ns as f64;
                    if next > sim_now_ns {
                        sim_idle_ns += next - sim_now_ns;
                        sim_now_ns = next;
                    }
                    continue;
                }
                if let Some(rx) = self.intake.take() {
                    // No simulated work left but the intake is open: block
                    // on the channel (host wall time, not simulated time).
                    // A disconnect leaves the intake closed (`None`).
                    if let Ok(req) = rx.recv() {
                        self.intake = Some(rx);
                        self.submit(Self::clamp_arrival(req, sim_now_ns));
                    }
                    continue;
                }
                break;
            }

            // One decode iteration across the batch.  The batch steps in
            // lockstep, so the shard clock advances by the slowest
            // member's per-token cost; each member's own service-time
            // accounting still charges its own bucket.
            decode_iterations += 1;
            occupancy_sum += running.len() as f64 / self.max_batch as f64;
            let mut iteration_ns = 0.0f64;
            for i in 0..running.len() {
                let t0 = Instant::now();
                let (mut next, token) = self.engine.step(&running[i].hidden)?;
                self.engine.feed_token(&mut next, token);
                let r = &mut running[i];
                r.hidden = next;
                r.tokens.push(token);
                r.wall_ns += t0.elapsed().as_nanos() as f64;

                let ctx = r.req.prompt.len() as u64 + r.tokens.len() as u64;
                let cost = self.decode_cost(ctx)?.total_ns();
                running[i].sim_ns += cost;
                iteration_ns = iteration_ns.max(cost);
            }
            sim_now_ns += iteration_ns;
            for r in &mut running {
                if r.tokens.len() == 1 {
                    // First decoded token lands at the end of this
                    // iteration on the shard clock.
                    r.first_token_at_ns = sim_now_ns;
                }
            }

            // Retire finished requests.
            let mut i = 0;
            while i < running.len() {
                if running[i].tokens.len() >= running[i].req.max_new_tokens {
                    let r = running.swap_remove(i);
                    done.push(RequestResult {
                        id: r.req.id,
                        tokens: r.tokens,
                        sim_ttft_ns: r.sim_ttft_ns,
                        sim_total_ns: r.sim_ns,
                        wall_ns: r.wall_ns,
                        arrival_ns: r.arrival_ns,
                        sim_first_token_at_ns: r.first_token_at_ns,
                        sim_finish_at_ns: sim_now_ns,
                        deadline_ns: r.req.deadline_ns.map(|d| d as f64),
                    });
                } else {
                    i += 1;
                }
            }
        }

        done.sort_by_key(|r| r.id);
        let total_tokens: usize = done.iter().map(|r| r.tokens.len()).sum();
        let sim_ns: f64 = done.iter().map(|r| r.sim_total_ns).sum();
        let wall_ns = wall_start.elapsed().as_nanos() as f64;
        let stats = ShardStats {
            shard: self.shard_id,
            requests: done.len(),
            tokens: total_tokens,
            sim_ns,
            wall_ns,
            sim_clock_ns: sim_now_ns,
            sim_idle_ns,
            decode_iterations,
            occupancy: if decode_iterations == 0 {
                0.0
            } else {
                occupancy_sum / decode_iterations as f64
            },
        };
        Ok(ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_now_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results: done,
            shards: vec![stats],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn server(max_batch: usize) -> Server<SyntheticEngine> {
        Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            max_batch,
        )
    }

    #[test]
    fn serves_all_requests() {
        let mut s = server(2);
        for id in 0..5 {
            s.submit(Request::new(id, vec![id as u32, 7], 6));
        }
        let report = s.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.total_tokens, 30);
        for r in &report.results {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.sim_ttft_ns > 0.0);
            assert!(r.sim_total_ns > r.sim_ttft_ns);
            assert!(r.sim_finish_at_ns > r.sim_first_token_at_ns);
            assert!(r.e2e_ns() > r.ttft_ns());
        }
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].tokens, 30);
        assert!(report.shards[0].occupancy > 0.0 && report.shards[0].occupancy <= 1.0);
        assert!(report.shards[0].sim_clock_ns > 0.0);
        assert_eq!(report.shards[0].sim_idle_ns, 0.0);
    }

    #[test]
    fn generation_is_deterministic() {
        let run = |batch| {
            let mut s = server(batch);
            s.submit(Request::new(0, vec![3, 1, 4], 8));
            s.run_to_completion().unwrap().results[0].tokens.clone()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn longer_prompts_cost_more_simulated_prefill() {
        let mut s = server(1);
        s.submit(Request::new(0, vec![1; 4], 1));
        s.submit(Request::new(1, vec![1; 512], 1));
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results[1].sim_ttft_ns > rep.results[0].sim_ttft_ns);
    }

    #[test]
    fn empty_server_reports_zero() {
        let mut s = server(1);
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.total_tokens, 0);
        assert!(rep.results.is_empty());
        assert_eq!(rep.shards[0].decode_iterations, 0);
    }

    #[test]
    fn zero_token_requests_retire_at_prefill() {
        let mut s = server(2);
        s.submit(Request::new(0, vec![1, 2], 0));
        s.submit(Request::new(1, vec![3], 0));
        s.submit(Request::new(2, vec![4], 0));
        s.submit(Request::new(3, vec![5, 6], 2));
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 4);
        assert_eq!(rep.total_tokens, 2);
        for r in &rep.results[..3] {
            assert!(r.tokens.is_empty(), "req {} must not decode", r.id);
            assert!(r.sim_ttft_ns > 0.0);
            assert_eq!(r.sim_total_ns, r.sim_ttft_ns);
            assert_eq!(r.sim_finish_at_ns, r.sim_first_token_at_ns);
        }
        assert_eq!(rep.results[3].tokens.len(), 2);
    }

    #[test]
    fn decode_cache_persists_across_runs() {
        let mut s = server(2);
        s.submit(Request::new(0, vec![5, 6], 4));
        s.run_to_completion().unwrap();
        let priced = s.decode_cache_len();
        assert!(priced >= 1, "first run must prime the bucket cache");
        let misses = s.racam().service().misses();

        // Same context buckets again: no new buckets, no new searches.
        s.submit(Request::new(1, vec![9, 2], 4));
        s.run_to_completion().unwrap();
        assert_eq!(s.decode_cache_len(), priced);
        assert_eq!(s.racam().service().misses(), misses);
    }

    #[test]
    fn timed_arrivals_wait_for_the_clock() {
        // A request arriving far in the simulated future is served after
        // the clock jumps, and the gap shows up as idle time.
        let mut s = server(2);
        s.submit(Request::new(0, vec![1, 2], 2));
        let late_arrival = 10_000_000_000_000u64; // way past any service time
        s.submit(Request::new(1, vec![3, 4], 2).at(late_arrival));
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 2);
        let late = &rep.results[1];
        assert_eq!(late.arrival_ns, late_arrival as f64);
        assert!(late.sim_finish_at_ns > late_arrival as f64);
        // TTFT excludes the time before arrival.
        assert!(late.ttft_ns() < late_arrival as f64 / 2.0);
        assert!(rep.shards[0].sim_idle_ns > 0.0, "clock jump must be idle-accounted");
        assert!(rep.shards[0].utilization() < 1.0);
    }

    #[test]
    fn queueing_delay_shows_in_ttft_not_in_intrinsic_prefill() {
        // Two requests, batch 1: the second waits for the first, so its
        // serving TTFT exceeds its intrinsic prefill cost.
        let mut s = server(1);
        s.submit(Request::new(0, vec![1, 2], 4));
        s.submit(Request::new(1, vec![3, 4], 4));
        let rep = s.run_to_completion().unwrap();
        let second = &rep.results[1];
        assert!(second.ttft_ns() > second.sim_ttft_ns * 1.5, "queue wait missing from TTFT");
    }

    #[test]
    fn intake_accepts_requests_mid_run() {
        let mut s = server(2);
        s.submit(Request::new(0, vec![1, 2], 3));
        let tx = s.open_intake();
        let worker = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            tx.send(Request::new(7, vec![9, 9], 3)).unwrap();
            // Dropping tx closes the intake and lets the run finish.
        });
        let rep = s.run_to_completion().unwrap();
        worker.join().unwrap();
        assert_eq!(rep.results.len(), 2);
        assert!(rep.results.iter().any(|r| r.id == 7 && r.tokens.len() == 3));
    }

    #[test]
    fn deadline_accounting() {
        let mut s = server(1);
        s.submit(Request::new(0, vec![1], 2).with_deadline(u64::MAX));
        s.submit(Request::new(1, vec![2], 2).with_deadline(1));
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results[0].met_deadline());
        assert!(!rep.results[1].met_deadline());
    }
}
