//! The serving loop: continuous-batched greedy decoding through a token
//! engine, with per-token RACAM latency accounting from the shared mapping
//! service (the simulated-hardware clock) next to the host wall clock.
//!
//! A [`Server`] is one worker shard: it owns a token engine, a
//! [`RacamSystem`] handle (typically sharing its [`MappingService`] with
//! every other shard — see [`super::Coordinator`]), a pluggable admission
//! [`Scheduler`] (FCFS by default), and a persistent per-context-bucket
//! decode-cost cache so repeated runs never re-price a bucket.
//!
//! [`MappingService`]: crate::mapping::MappingService

use super::batcher::FcfsBatcher;
use super::engine::TokenEngine;
use super::scheduler::Scheduler;
use crate::config::LlmSpec;
use crate::metrics::LatencyBreakdown;
use crate::workloads::{decode_kernels, prefill_kernels, stage_latency, RacamSystem};
use crate::Result;
use std::collections::HashMap;
use std::time::Instant;

/// An inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

/// Completed request with its generation and accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Simulated RACAM time to first token (prefill), ns.
    pub sim_ttft_ns: f64,
    /// Simulated RACAM end-to-end latency, ns.
    pub sim_total_ns: f64,
    /// Host wall-clock spent executing this request's share, ns.
    pub wall_ns: f64,
}

/// Per-shard utilization accounting (one entry per worker).
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests this shard completed.
    pub requests: usize,
    /// Tokens this shard generated.
    pub tokens: usize,
    /// Summed simulated RACAM time of this shard's requests, ns.
    pub sim_ns: f64,
    /// Host wall-clock of this shard's serving loop, ns.
    pub wall_ns: f64,
    /// Decode iterations executed.
    pub decode_iterations: usize,
    /// Mean fraction of batch slots occupied across decode iterations
    /// (1.0 = the shard decoded at full batch the whole run).
    pub occupancy: f64,
}

/// Aggregate serving report (single shard or merged across shards).
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub results: Vec<RequestResult>,
    pub sim_tokens_per_s: f64,
    pub wall_tokens_per_s: f64,
    pub total_tokens: usize,
    /// Per-shard utilization; one entry for a plain [`Server`] run, one per
    /// worker for a [`super::Coordinator`] run.
    pub shards: Vec<ShardStats>,
}

impl ServerReport {
    /// Merge per-shard reports into one, re-sorting results by request id.
    /// Shards run concurrently (each modeling its own RACAM device until
    /// per-shard channel partitioning lands), so both clocks use the
    /// makespan — the slowest shard — rather than a sum: `wall_ns` is the
    /// coordinator-level wall clock, and simulated throughput divides by
    /// the largest per-shard simulated time.
    pub fn merge(reports: Vec<ServerReport>, wall_ns: f64) -> ServerReport {
        let mut results: Vec<RequestResult> = Vec::new();
        let mut shards: Vec<ShardStats> = Vec::new();
        for r in reports {
            results.extend(r.results);
            shards.extend(r.shards);
        }
        results.sort_by_key(|r| r.id);
        shards.sort_by_key(|s| s.shard);
        let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let sim_makespan_ns = shards.iter().map(|s| s.sim_ns).fold(0.0f64, f64::max);
        ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_makespan_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results,
            shards,
        }
    }
}

/// One serving worker (see module docs).
pub struct Server<E: TokenEngine, S: Scheduler = FcfsBatcher> {
    engine: E,
    racam: RacamSystem,
    spec: LlmSpec,
    scheduler: S,
    max_batch: usize,
    shard_id: usize,
    /// Simulated per-token decode cost per context bucket, kept across
    /// runs so repeated runs (and long-lived shards) reuse priced buckets.
    decode_cache: HashMap<u64, LatencyBreakdown>,
}

struct Running {
    req: Request,
    hidden: Vec<f32>,
    tokens: Vec<u32>,
    sim_ns: f64,
    sim_ttft_ns: f64,
    wall_ns: f64,
}

impl<E: TokenEngine> Server<E, FcfsBatcher> {
    /// `spec` names the LLM whose kernel shapes the RACAM clock prices
    /// (the toy engine generates real tokens; the simulator accounts what
    /// the full-size model would cost on RACAM hardware).
    pub fn new(engine: E, racam: RacamSystem, spec: LlmSpec, max_batch: usize) -> Self {
        let scheduler = FcfsBatcher::new(max_batch);
        Server::with_scheduler(engine, racam, spec, max_batch, scheduler)
    }
}

impl<E: TokenEngine, S: Scheduler> Server<E, S> {
    /// A server with an explicit admission policy.
    pub fn with_scheduler(
        engine: E,
        racam: RacamSystem,
        spec: LlmSpec,
        max_batch: usize,
        scheduler: S,
    ) -> Self {
        assert!(max_batch >= 1);
        Server {
            engine,
            racam,
            spec,
            scheduler,
            max_batch,
            shard_id: 0,
            decode_cache: HashMap::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.scheduler.submit(req);
    }

    /// Requests waiting for admission.
    pub fn pending(&self) -> usize {
        self.scheduler.pending()
    }

    /// Access the simulated-hardware pipeline (e.g. to persist its mapping
    /// cache after a run, §7 amortization).
    pub fn racam(&self) -> &RacamSystem {
        &self.racam
    }

    /// Priced decode context buckets held in server state.
    pub fn decode_cache_len(&self) -> usize {
        self.decode_cache.len()
    }

    /// Label this worker for per-shard reporting (set by the coordinator).
    pub(crate) fn set_shard(&mut self, id: usize) {
        self.shard_id = id;
    }

    /// Drain all submitted requests to completion.
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        let mut running: Vec<Running> = Vec::new();
        let mut done: Vec<RequestResult> = Vec::new();
        let wall_start = Instant::now();
        let mut decode_iterations = 0usize;
        let mut occupancy_sum = 0.0f64;

        loop {
            // Admit new work (continuous batching).
            let slots = self.max_batch.saturating_sub(running.len());
            let mut admitted = 0usize;
            for req in self.scheduler.next_batch(slots) {
                admitted += 1;
                let t0 = Instant::now();
                let hidden = self.engine.embed_prompt(&req.prompt);
                // Simulated prefill cost for this prompt length.
                let kernels = prefill_kernels(&self.spec, req.prompt.len() as u64);
                let prefill = stage_latency(&self.racam, &kernels)?;
                if req.max_new_tokens == 0 {
                    // Nothing to decode: retire immediately (prefill-only).
                    done.push(RequestResult {
                        id: req.id,
                        tokens: Vec::new(),
                        sim_ttft_ns: prefill.total_ns(),
                        sim_total_ns: prefill.total_ns(),
                        wall_ns: t0.elapsed().as_nanos() as f64,
                    });
                    continue;
                }
                running.push(Running {
                    hidden,
                    tokens: Vec::new(),
                    sim_ns: prefill.total_ns(),
                    sim_ttft_ns: prefill.total_ns(),
                    wall_ns: t0.elapsed().as_nanos() as f64,
                    req,
                });
            }
            if running.is_empty() {
                if self.scheduler.pending() == 0 {
                    break;
                }
                if admitted == 0 {
                    // The scheduler returned nothing while work is queued
                    // and every batch slot is free: that violates the
                    // `Scheduler::next_batch` contract and would spin this
                    // clockless loop forever.
                    anyhow::bail!(
                        "scheduler withheld {} queued request(s) with {} free slots",
                        self.scheduler.pending(),
                        self.max_batch
                    );
                }
                // Everything admitted this round retired at prefill
                // (zero-token requests); keep draining the queue.
                continue;
            }

            // One decode iteration across the batch.
            decode_iterations += 1;
            occupancy_sum += running.len() as f64 / self.max_batch as f64;
            for r in &mut running {
                let t0 = Instant::now();
                let (mut next, token) = self.engine.step(&r.hidden)?;
                self.engine.feed_token(&mut next, token);
                r.hidden = next;
                r.tokens.push(token);
                r.wall_ns += t0.elapsed().as_nanos() as f64;

                let ctx = r.req.prompt.len() as u64 + r.tokens.len() as u64;
                // Simulated per-token decode cost (cached per context
                // bucket of 256 to bound search work; the bucket cache is
                // server state, so repeated runs reuse it).
                let bucket = ctx.div_ceil(256) * 256;
                if !self.decode_cache.contains_key(&bucket) {
                    let cost = stage_latency(&self.racam, &decode_kernels(&self.spec, bucket))?;
                    self.decode_cache.insert(bucket, cost);
                }
                r.sim_ns += self.decode_cache[&bucket].total_ns();
            }

            // Retire finished requests.
            let mut i = 0;
            while i < running.len() {
                if running[i].tokens.len() >= running[i].req.max_new_tokens {
                    let r = running.swap_remove(i);
                    done.push(RequestResult {
                        id: r.req.id,
                        tokens: r.tokens,
                        sim_ttft_ns: r.sim_ttft_ns,
                        sim_total_ns: r.sim_ns,
                        wall_ns: r.wall_ns,
                    });
                } else {
                    i += 1;
                }
            }
        }

        done.sort_by_key(|r| r.id);
        let total_tokens: usize = done.iter().map(|r| r.tokens.len()).sum();
        let sim_ns: f64 = done.iter().map(|r| r.sim_total_ns).sum();
        let wall_ns = wall_start.elapsed().as_nanos() as f64;
        let stats = ShardStats {
            shard: self.shard_id,
            requests: done.len(),
            tokens: total_tokens,
            sim_ns,
            wall_ns,
            decode_iterations,
            occupancy: if decode_iterations == 0 {
                0.0
            } else {
                occupancy_sum / decode_iterations as f64
            },
        };
        Ok(ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results: done,
            shards: vec![stats],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn server(max_batch: usize) -> Server<SyntheticEngine> {
        Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            max_batch,
        )
    }

    #[test]
    fn serves_all_requests() {
        let mut s = server(2);
        for id in 0..5 {
            s.submit(Request { id, prompt: vec![id as u32, 7], max_new_tokens: 6 });
        }
        let report = s.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.total_tokens, 30);
        for r in &report.results {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.sim_ttft_ns > 0.0);
            assert!(r.sim_total_ns > r.sim_ttft_ns);
        }
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].tokens, 30);
        assert!(report.shards[0].occupancy > 0.0 && report.shards[0].occupancy <= 1.0);
    }

    #[test]
    fn generation_is_deterministic() {
        let run = |batch| {
            let mut s = server(batch);
            s.submit(Request { id: 0, prompt: vec![3, 1, 4], max_new_tokens: 8 });
            s.run_to_completion().unwrap().results[0].tokens.clone()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn longer_prompts_cost_more_simulated_prefill() {
        let mut s = server(1);
        s.submit(Request { id: 0, prompt: vec![1; 4], max_new_tokens: 1 });
        s.submit(Request { id: 1, prompt: vec![1; 512], max_new_tokens: 1 });
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results[1].sim_ttft_ns > rep.results[0].sim_ttft_ns);
    }

    #[test]
    fn empty_server_reports_zero() {
        let mut s = server(1);
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.total_tokens, 0);
        assert!(rep.results.is_empty());
        assert_eq!(rep.shards[0].decode_iterations, 0);
    }

    #[test]
    fn zero_token_requests_retire_at_prefill() {
        let mut s = server(2);
        s.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 0 });
        s.submit(Request { id: 1, prompt: vec![3], max_new_tokens: 0 });
        s.submit(Request { id: 2, prompt: vec![4], max_new_tokens: 0 });
        s.submit(Request { id: 3, prompt: vec![5, 6], max_new_tokens: 2 });
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 4);
        assert_eq!(rep.total_tokens, 2);
        for r in &rep.results[..3] {
            assert!(r.tokens.is_empty(), "req {} must not decode", r.id);
            assert!(r.sim_ttft_ns > 0.0);
            assert_eq!(r.sim_total_ns, r.sim_ttft_ns);
        }
        assert_eq!(rep.results[3].tokens.len(), 2);
    }

    #[test]
    fn decode_cache_persists_across_runs() {
        let mut s = server(2);
        s.submit(Request { id: 0, prompt: vec![5, 6], max_new_tokens: 4 });
        s.run_to_completion().unwrap();
        let priced = s.decode_cache_len();
        assert!(priced >= 1, "first run must prime the bucket cache");
        let misses = s.racam().service().misses();

        // Same context buckets again: no new buckets, no new searches.
        s.submit(Request { id: 1, prompt: vec![9, 2], max_new_tokens: 4 });
        s.run_to_completion().unwrap();
        assert_eq!(s.decode_cache_len(), priced);
        assert_eq!(s.racam().service().misses(), misses);
    }
}
