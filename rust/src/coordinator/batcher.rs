//! FCFS dynamic batcher: groups pending requests up to a batch-size cap,
//! admitting new arrivals between decode iterations (continuous batching à
//! la vLLM, degenerating to the paper's batch-size-1 setting when cap = 1).
//! It is the default [`Scheduler`] of [`super::Server`].

use super::scheduler::Scheduler;
use super::server::Request;
use std::collections::VecDeque;

/// Token-count granularity shared by every consumer that buckets by
/// sequence length: the server's per-context decode-cost cache, its
/// prompt-bucketed prefill cache, and the length-bucketed scheduler.  One
/// public constant so policies and caches agree on boundaries instead of
/// duplicating a magic number.
pub const BUCKET_TOKENS: u64 = 256;

/// The bucket boundary a token count falls under: the smallest multiple of
/// [`BUCKET_TOKENS`] at or above `tokens` (minimum one bucket, so empty
/// prompts still price a non-degenerate kernel set).
pub fn ctx_bucket(tokens: u64) -> u64 {
    tokens.max(1).div_ceil(BUCKET_TOKENS) * BUCKET_TOKENS
}

/// A scheduled batch of request ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    pub request_ids: Vec<u64>,
}

/// First-come-first-served batcher with a maximum batch size.
#[derive(Debug)]
pub struct FcfsBatcher {
    max_batch: usize,
    queue: VecDeque<Request>,
}

impl FcfsBatcher {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        FcfsBatcher { max_batch, queue: VecDeque::new() }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admit up to `slots_free` additional requests (bounded by max batch).
    pub fn admit(&mut self, running: usize) -> Vec<Request> {
        let slots = self.max_batch.saturating_sub(running);
        self.next_batch(slots)
    }
}

impl Scheduler for FcfsBatcher {
    fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn next_batch(&mut self, slots: usize) -> Vec<Request> {
        let mut out = Vec::new();
        self.next_batch_into(slots, &mut out);
        out
    }

    fn next_batch_into(&mut self, slots: usize, out: &mut Vec<Request>) {
        let take = slots.min(self.queue.len());
        out.extend(self.queue.drain(..take));
    }

    fn preempt_horizon(&self, _req: &Request, _generated: usize) -> Option<f64> {
        // FCFS never preempts (the default `should_preempt` keeps
        // everything and touches no state), so the verdict never changes.
        Some(f64::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Request;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn bucket_boundaries() {
        assert_eq!(ctx_bucket(0), BUCKET_TOKENS);
        assert_eq!(ctx_bucket(1), BUCKET_TOKENS);
        assert_eq!(ctx_bucket(BUCKET_TOKENS), BUCKET_TOKENS);
        assert_eq!(ctx_bucket(BUCKET_TOKENS + 1), 2 * BUCKET_TOKENS);
        assert_eq!(ctx_bucket(1000), 4 * BUCKET_TOKENS);
    }

    #[test]
    fn fcfs_order_preserved() {
        let mut b = FcfsBatcher::new(2);
        b.submit(req(1));
        b.submit(req(2));
        b.submit(req(3));
        let first = b.admit(0);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn respects_running_slots() {
        let mut b = FcfsBatcher::new(4);
        for i in 0..6 {
            b.submit(req(i));
        }
        assert_eq!(b.admit(3).len(), 1); // only one free slot
        assert_eq!(b.admit(0).len(), 4);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn batch_size_one_is_paper_setting() {
        let mut b = FcfsBatcher::new(1);
        b.submit(req(1));
        b.submit(req(2));
        assert_eq!(b.admit(0).len(), 1);
        assert_eq!(b.admit(1).len(), 0);
    }
}
