//! [`ClusterBuilder`] — the single constructor of the serving stack: it
//! turns a declarative [`ClusterSpec`] into a role-aware [`Coordinator`].
//!
//! The builder replaces the old constructor sprawl (`Coordinator::new`,
//! `with_service`, `with_schedulers`, `with_shard_services`, post-hoc
//! `set_policy`), all of which are now thin deprecated wrappers over it:
//!
//! ```no_run
//! use racam::config::{gpt3_6_7b, racam_paper, ClusterSpec};
//! use racam::coordinator::{ClusterBuilder, SyntheticEngine};
//!
//! let spec = ClusterSpec::disaggregated(2, 2, 4);
//! let mut coord = ClusterBuilder::new(spec, &racam_paper(), gpt3_6_7b())
//!     .unwrap()
//!     .build(|_| SyntheticEngine::new(64, 256));
//! # let _ = coord.run_to_completion();
//! ```
//!
//! Building validates the spec twice over: the hardware-independent rules
//! of [`ClusterSpec::validate`] (balanced roles, non-zero counts, legal
//! policies), then the channel shares against the concrete device — group
//! shares must sum *exactly* to the device's DRAM channels, so a
//! disaggregated cluster still aggregates to the paper device the way the
//! flat partition did.  Shards with equal channel counts share one mapping
//! service across the whole cluster (a mapping priced for 4 channels is
//! valid on every 4-channel shard, whichever group owns it).  When the
//! spec names a [`mapping_store`](ClusterSpec::mapping_store), the builder
//! threads that warm table through every distinct service: each loads the
//! file at construction and merges its cache back on drop, so repeated
//! runs (and concurrent processes) skip the mapping search entirely for
//! shapes any of them has already priced — see `docs/mapping.md`.

use super::engine::TokenEngine;
use super::multi::Coordinator;
use super::scheduler::{EdfScheduler, LengthBucketed, Scheduler};
use super::server::Server;
use super::FcfsBatcher;
use crate::config::{partition_channels, ClusterSpec, HwConfig, LlmSpec, SchedulerKind};
use crate::mapping::MappingService;
use crate::telemetry::{NopRecorder, Recorder};
use crate::workloads::RacamSystem;
use crate::Result;
use std::collections::HashMap;

/// A coordinator whose shards may each run a different admission policy
/// (what [`ClusterBuilder::build`] yields — per-group [`SchedulerKind`]s
/// resolve to boxed schedulers at build time).  The second parameter is
/// the telemetry sink ([`NopRecorder`] unless built with
/// [`ClusterBuilder::build_recorded`]).
pub type ClusterCoordinator<E, R = NopRecorder> = Coordinator<E, Box<dyn Scheduler>, R>;

/// Builds a [`Coordinator`] from a [`ClusterSpec`] (see module docs).
pub struct ClusterBuilder {
    spec: ClusterSpec,
    model: LlmSpec,
    /// Pre-computed (or caller-supplied) mapping service per shard.
    services: Vec<MappingService>,
}

impl ClusterBuilder {
    /// Validate `spec` against `hw` and partition the device's DRAM
    /// channels across the spec's shards: explicit group shares are split
    /// within each group; absent shares, channels partition evenly across
    /// all shards exactly as the flat coordinator did (falling back to
    /// sharing the full config when there are more shards than channels).
    pub fn new(spec: ClusterSpec, hw: &HwConfig, model: LlmSpec) -> Result<Self> {
        spec.validate().map_err(|e| anyhow::anyhow!("invalid cluster spec: {e}"))?;
        let services = Self::partition(&spec, hw)?;
        Self::attach_warm_store(&spec, &services)?;
        Ok(ClusterBuilder { spec, model, services })
    }

    /// Thread the spec's warm mapping store (if any) through every
    /// *distinct* mapping service: equal-channel shards alias one service,
    /// so the table loads once per channel count and each distinct service
    /// merges its cache back into the same file on drop.  Caller-supplied
    /// services ([`ClusterBuilder::with_spec_and_services`]) are left
    /// untouched — they are the caller's to warm.
    fn attach_warm_store(spec: &ClusterSpec, services: &[MappingService]) -> Result<()> {
        let Some(path) = &spec.mapping_store else { return Ok(()) };
        let mut seen: Vec<&MappingService> = Vec::new();
        for svc in services {
            if seen.iter().any(|s| s.shares_cache_with(svc)) {
                continue;
            }
            svc.set_warm_path(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("mapping store '{path}': {e}"))?;
            seen.push(svc);
        }
        Ok(())
    }

    /// Build over caller-supplied per-shard mapping services (pre-warmed
    /// caches, or experiment matrices that must price every cell from the
    /// same caches).  `services.len()` must equal the spec's total shards;
    /// channel shares in the spec are ignored — the services *are* the
    /// hardware assignment.
    pub fn with_spec_and_services(
        spec: ClusterSpec,
        model: LlmSpec,
        services: Vec<MappingService>,
    ) -> Result<Self> {
        spec.validate().map_err(|e| anyhow::anyhow!("invalid cluster spec: {e}"))?;
        anyhow::ensure!(
            services.len() == spec.total_shards(),
            "{} mapping service(s) for {} shard(s)",
            services.len(),
            spec.total_shards()
        );
        Ok(ClusterBuilder { spec, model, services })
    }

    /// The per-shard mapping services this builder will hand to the
    /// coordinator (equal channel counts alias one service).
    pub fn services(&self) -> &[MappingService] {
        &self.services
    }

    /// The validated spec.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    fn partition(spec: &ClusterSpec, hw: &HwConfig) -> Result<Vec<MappingService>> {
        let explicit = spec.groups.iter().any(|g| g.channels.is_some());
        // Equal-channel shards share one mapping service cluster-wide.
        let mut by_channels: HashMap<u32, MappingService> = HashMap::new();
        let mut service_for = |cfg: &HwConfig| {
            by_channels
                .entry(cfg.dram.channels)
                .or_insert_with(|| MappingService::for_config(cfg))
                .clone()
        };
        if explicit {
            let total: u32 = spec.groups.iter().map(|g| g.channels.unwrap_or(0)).sum();
            anyhow::ensure!(
                total == hw.dram.channels,
                "group channel shares sum to {total}, device has {} channels",
                hw.dram.channels
            );
            let mut services = Vec::with_capacity(spec.total_shards());
            for g in &spec.groups {
                // `ClusterSpec::validate` enforces all-or-none shares and
                // share >= count, but propagate instead of panicking in
                // case a caller skips validation.
                let Some(share) = g.channels else {
                    anyhow::bail!("group '{}' lacks a channel share (all-or-none)", g.name);
                };
                let mut group_hw = hw.clone();
                group_hw.dram.channels = share;
                let Some(parts) = partition_channels(&group_hw, g.count) else {
                    anyhow::bail!(
                        "group '{}': channel share {share} cannot cover {} shard(s)",
                        g.name,
                        g.count
                    );
                };
                services.extend(parts.iter().map(&mut service_for));
            }
            Ok(services)
        } else {
            // The legacy flat partition across all shards, bit-for-bit
            // (same fallback: more shards than channels ⇒ everyone shares
            // the full config).
            match partition_channels(hw, spec.total_shards()) {
                Some(parts) => Ok(parts.iter().map(&mut service_for).collect()),
                None => {
                    let shared = MappingService::for_config(hw);
                    Ok(vec![shared; spec.total_shards()])
                }
            }
        }
    }

    /// Build with per-group schedulers resolved from each group's
    /// [`SchedulerKind`].  `engine_factory` is called once per shard in
    /// global shard order.
    pub fn build<E: TokenEngine + Send>(
        self,
        engine_factory: impl FnMut(usize) -> E,
    ) -> ClusterCoordinator<E> {
        let mk: Vec<(SchedulerKind, usize)> =
            self.spec.groups.iter().map(|g| (g.scheduler, g.max_batch)).collect();
        let group_of = self.group_of_shard();
        self.build_with(engine_factory, move |i| {
            let (kind, max_batch) = mk[group_of[i]];
            match kind {
                SchedulerKind::Fcfs => {
                    Box::new(FcfsBatcher::new(max_batch)) as Box<dyn Scheduler>
                }
                SchedulerKind::Bucketed => Box::new(LengthBucketed::new()),
                SchedulerKind::Edf => Box::new(EdfScheduler::new()),
            }
        })
    }

    /// Build with an explicit scheduler factory (the seam the deprecated
    /// `Coordinator` constructors and scheduler-comparison experiments
    /// use); the groups' [`SchedulerKind`]s are ignored.
    pub fn build_with<E: TokenEngine + Send, S: Scheduler>(
        self,
        engine_factory: impl FnMut(usize) -> E,
        scheduler_factory: impl FnMut(usize) -> S,
    ) -> Coordinator<E, S> {
        self.build_core(engine_factory, scheduler_factory, |_| NopRecorder, NopRecorder)
    }

    /// Like [`ClusterBuilder::build`], but with a telemetry [`Recorder`]
    /// attached to every shard (`recorder_factory`, called once per shard
    /// in global shard order) and one more for the KV-link track
    /// (`link_recorder`, owned by the coordinator).  The recorders are
    /// pure observers: a recorded run is bit-identical to an unrecorded
    /// one — the engine-equivalence suite enforces this.
    pub fn build_recorded<E: TokenEngine + Send, R: Recorder + Send>(
        self,
        engine_factory: impl FnMut(usize) -> E,
        recorder_factory: impl FnMut(usize) -> R,
        link_recorder: R,
    ) -> ClusterCoordinator<E, R> {
        let mk: Vec<(SchedulerKind, usize)> =
            self.spec.groups.iter().map(|g| (g.scheduler, g.max_batch)).collect();
        let group_of = self.group_of_shard();
        self.build_core(
            engine_factory,
            move |i| {
                let (kind, max_batch) = mk[group_of[i]];
                match kind {
                    SchedulerKind::Fcfs => {
                        Box::new(FcfsBatcher::new(max_batch)) as Box<dyn Scheduler>
                    }
                    SchedulerKind::Bucketed => Box::new(LengthBucketed::new()),
                    SchedulerKind::Edf => Box::new(EdfScheduler::new()),
                }
            },
            recorder_factory,
            link_recorder,
        )
    }

    /// The one construction path behind `build` / `build_with` /
    /// `build_recorded`: resolve services, wire each shard's engine,
    /// scheduler, and recorder, and hand the lot to the coordinator.
    fn build_core<E: TokenEngine + Send, S: Scheduler, R: Recorder + Send>(
        self,
        mut engine_factory: impl FnMut(usize) -> E,
        mut scheduler_factory: impl FnMut(usize) -> S,
        mut recorder_factory: impl FnMut(usize) -> R,
        link_recorder: R,
    ) -> Coordinator<E, S, R> {
        let group_of = self.group_of_shard();
        let ClusterBuilder { spec, model, services } = self;
        let mut shards: Vec<Server<E, S, R>> = Vec::with_capacity(services.len());
        for (i, svc) in services.iter().enumerate() {
            let group = &spec.groups[group_of[i]];
            let mut server = Server::with_scheduler(
                engine_factory(i),
                RacamSystem::with_service(svc.clone()),
                model.clone(),
                group.max_batch,
                scheduler_factory(i),
            )
            .with_recorder(recorder_factory(i));
            server.set_shard(i);
            server.set_group(&group.name);
            server.set_role(group.role);
            server.set_policy(group.policy);
            shards.push(server);
        }
        Coordinator::from_parts(shards, services, model, spec.kv_link_gbps, link_recorder)
    }

    /// Group index of each global shard index.
    fn group_of_shard(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.spec.total_shards());
        for (gi, g) in self.spec.groups.iter().enumerate() {
            out.extend(std::iter::repeat(gi).take(g.count));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        racam_paper, LlmSpec, Precision, ServingPolicy, ShardGroup, ShardRole,
    };
    use crate::coordinator::engine::SyntheticEngine;
    use crate::coordinator::server::Request;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn build(spec: ClusterSpec) -> ClusterCoordinator<SyntheticEngine> {
        ClusterBuilder::new(spec, &racam_paper(), tiny_spec())
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128))
    }

    #[test]
    fn unified_spec_matches_legacy_constructor_bit_for_bit() {
        // The builder-equivalence acceptance: ClusterSpec::unified(n)
        // reproduces Coordinator::new exactly — same tokens, same
        // simulated timestamps, same per-shard services.
        let run_new = || {
            #[allow(deprecated)]
            let mut c = Coordinator::new(&racam_paper(), tiny_spec(), 3, 2, |_| {
                SyntheticEngine::new(64, 128)
            });
            for id in 0..7 {
                c.submit(Request::new(id, vec![id as u32 % 5, 2], 6));
            }
            c.run_to_completion().unwrap()
        };
        let run_builder = || {
            let mut c = build(ClusterSpec::unified(3, 2));
            for id in 0..7 {
                c.submit(Request::new(id, vec![id as u32 % 5, 2], 6));
            }
            c.run_to_completion().unwrap()
        };
        let a = run_new();
        let b = run_builder();
        assert_eq!(a.results.len(), b.results.len());
        assert_eq!(a.total_tokens, b.total_tokens);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.sim_ttft_ns.to_bits(), y.sim_ttft_ns.to_bits());
            assert_eq!(x.sim_total_ns.to_bits(), y.sim_total_ns.to_bits());
            assert_eq!(x.sim_finish_at_ns.to_bits(), y.sim_finish_at_ns.to_bits());
        }
        for (sa, sb) in a.shards.iter().zip(&b.shards) {
            assert_eq!(sa.shard, sb.shard);
            assert_eq!(sa.requests, sb.requests);
            assert_eq!(sa.sim_clock_ns.to_bits(), sb.sim_clock_ns.to_bits());
            assert_eq!(sa.handoffs, 0);
            assert_eq!(sb.handoffs, 0);
        }
    }

    #[test]
    fn builder_partitions_channels_like_the_flat_coordinator() {
        let b = ClusterBuilder::new(ClusterSpec::unified(3, 2), &racam_paper(), tiny_spec())
            .unwrap();
        let ch: Vec<u32> = b.services().iter().map(|s| s.hw().hw.dram.channels).collect();
        assert_eq!(ch, vec![3, 3, 2]);
    }

    #[test]
    fn warm_store_threads_through_every_service_and_survives_rebuilds() {
        use crate::config::{MatmulShape, Precision};
        let path = std::env::temp_dir()
            .join(format!("racam_cluster_warm_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let spec =
            || ClusterSpec::unified(3, 2).with_mapping_store(path.to_str().unwrap());
        let shape = MatmulShape::new(64, 256, 256, Precision::Int8);
        {
            let b = ClusterBuilder::new(spec(), &racam_paper(), tiny_spec()).unwrap();
            // Every service carries the warm path (3-3-2 partition: two
            // distinct services behind three shards).
            for s in b.services() {
                assert_eq!(s.warm_path().as_deref(), Some(path.as_path()));
                assert_eq!(s.warm_loads(), 0, "nothing to load on a cold store");
            }
            assert!(b.services()[0].shares_cache_with(&b.services()[1]));
            assert!(!b.services()[0].shares_cache_with(&b.services()[2]));
            // Price one shape on each distinct service, then drop: both
            // merge into the same file.
            b.services()[0].search_cached(&shape).unwrap();
            b.services()[2].search_cached(&shape).unwrap();
        }
        assert!(path.exists(), "services must persist their caches on drop");
        let b = ClusterBuilder::new(spec(), &racam_paper(), tiny_spec()).unwrap();
        // The 3-channel service loads the 3-channel entry, the 2-channel
        // service the 2-channel one — channel keying keeps them apart.
        assert_eq!(b.services()[0].warm_loads(), 1);
        assert_eq!(b.services()[2].warm_loads(), 1);
        b.services()[0].search_cached(&shape).unwrap();
        b.services()[2].search_cached(&shape).unwrap();
        assert_eq!(b.services()[0].misses() + b.services()[2].misses(), 0);
        assert_eq!(b.services()[0].hits() + b.services()[2].hits(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn explicit_group_shares_partition_within_groups() {
        let spec = ClusterSpec {
            groups: vec![
                ShardGroup::unified("prefill", 2, 4)
                    .with_role(ShardRole::Prefill)
                    .with_channels(6),
                ShardGroup::unified("decode", 1, 4)
                    .with_role(ShardRole::Decode)
                    .with_channels(2),
            ],
            kv_link_gbps: 64.0,
            mapping_store: None,
        };
        let b = ClusterBuilder::new(spec, &racam_paper(), tiny_spec()).unwrap();
        let ch: Vec<u32> = b.services().iter().map(|s| s.hw().hw.dram.channels).collect();
        assert_eq!(ch, vec![3, 3, 2]);
        // Aggregate capacity is still exactly the paper device.
        let agg: u64 = b.services().iter().map(|s| s.hw().hw.capacity_bytes()).sum();
        assert_eq!(agg, racam_paper().capacity_bytes());
    }

    #[test]
    fn oversubscribed_channel_shares_rejected() {
        // 6 + 4 = 10 > the paper device's 8 channels.
        let spec = ClusterSpec {
            groups: vec![
                ShardGroup::unified("p", 2, 4).with_role(ShardRole::Prefill).with_channels(6),
                ShardGroup::unified("d", 2, 4).with_role(ShardRole::Decode).with_channels(4),
            ],
            kv_link_gbps: 64.0,
            mapping_store: None,
        };
        let err = ClusterBuilder::new(spec, &racam_paper(), tiny_spec())
            .err()
            .expect("over-subscription must fail")
            .to_string();
        assert!(err.contains("sum to 10"), "unexpected error: {err}");
    }

    #[test]
    fn invalid_spec_rejected_by_builder_too() {
        let spec = ClusterSpec {
            groups: vec![ShardGroup::unified("d", 2, 4).with_role(ShardRole::Decode)],
            kv_link_gbps: 64.0,
            mapping_store: None,
        };
        assert!(ClusterBuilder::new(spec, &racam_paper(), tiny_spec()).is_err());
    }

    #[test]
    fn service_count_mismatch_rejected() {
        let svc = MappingService::for_config(&racam_paper());
        let err = ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(3, 2),
            tiny_spec(),
            vec![svc; 2],
        )
        .err()
        .expect("len mismatch must fail")
        .to_string();
        assert!(err.contains("2 mapping service(s) for 3 shard(s)"), "{err}");
    }

    #[test]
    fn per_group_schedulers_and_policies_apply() {
        let spec = ClusterSpec {
            groups: vec![
                ShardGroup::unified("prefill", 1, 4)
                    .with_role(ShardRole::Prefill)
                    .with_scheduler(SchedulerKind::Edf)
                    .with_policy(ServingPolicy::chunked(128)),
                ShardGroup::unified("decode", 1, 4).with_role(ShardRole::Decode),
            ],
            kv_link_gbps: 64.0,
            mapping_store: None,
        };
        let c = build(spec);
        assert_eq!(
            c.roles(),
            &[ShardRole::Prefill, ShardRole::Decode],
            "roles must follow group order"
        );
        assert!(c.is_disaggregated());
        // Shard 0 carries the prefill group's chunked policy.
        assert_eq!(c.policy(), ServingPolicy::chunked(128));
    }

    #[test]
    fn disaggregated_cluster_serves_end_to_end_with_kv_transfer() {
        // Acceptance: a disaggregated run completes every request, decode
        // shards report nonzero kv_transfer_ns, and generation matches the
        // unified cluster token-for-token.
        let serve = |spec: ClusterSpec| {
            let mut c = build(spec);
            for id in 0..6 {
                c.submit(Request::new(id, vec![id as u32 % 5, 3, 9], 5));
            }
            c.run_to_completion().unwrap()
        };
        let unified = serve(ClusterSpec::unified(4, 2));
        let disagg = serve(ClusterSpec::disaggregated(2, 2, 2));
        assert_eq!(disagg.results.len(), 6);
        assert_eq!(disagg.total_tokens, 30);
        let tok = |rep: &crate::coordinator::ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&unified), tok(&disagg), "disaggregation must not change generation");
        let kv: f64 = disagg
            .shards
            .iter()
            .filter(|s| s.role == ShardRole::Decode)
            .map(|s| s.kv_transfer_ns)
            .sum();
        assert!(kv > 0.0, "decode shards must charge KV-transfer time");
        // Every request crossed the link exactly once, visible from both
        // ends.
        let sent: usize = disagg
            .shards
            .iter()
            .filter(|s| s.role == ShardRole::Prefill)
            .map(|s| s.handoffs)
            .sum();
        let recv: usize = disagg
            .shards
            .iter()
            .filter(|s| s.role == ShardRole::Decode)
            .map(|s| s.handoffs)
            .sum();
        assert_eq!(sent, 6);
        assert_eq!(recv, 6);
        // Unified runs never touch the link.
        assert!(unified.shards.iter().all(|s| s.handoffs == 0 && s.kv_transfer_ns == 0.0));
    }

    #[test]
    fn decode_shards_never_receive_fresh_prompts() {
        // Satellite regression: least-loaded dispatch and round-robin
        // intake both skip decode-only shards, so a decode shard never
        // prefills a fresh prompt (its prefill_chunks stay zero — all its
        // work arrives pre-prefilled over the KV link).
        let mut c = build(ClusterSpec::disaggregated(1, 2, 2));
        for id in 0..5 {
            c.submit(Request::new(id, vec![1, 2, 3], 4));
        }
        let mut intake = c.intake();
        assert_eq!(
            intake.num_shards(),
            1,
            "intake must only cover fresh-prompt-eligible shards"
        );
        #[allow(clippy::disallowed_methods)] // test harness thread
        let submitter = std::thread::spawn(move || {
            assert!(intake.submit(Request::new(100, vec![4, 4], 3)));
        });
        let report = c.run_to_completion().unwrap();
        submitter.join().unwrap();
        assert_eq!(report.results.len(), 6);
        for s in &report.shards {
            match s.role {
                ShardRole::Decode => {
                    assert_eq!(
                        s.prefill_chunks, 0,
                        "decode shard {} prefilled a fresh prompt",
                        s.shard
                    );
                    assert!(s.tokens > 0, "decode shard {} decoded nothing", s.shard);
                }
                _ => {
                    assert!(s.prefill_chunks > 0);
                    assert_eq!(s.tokens, 0, "prefill shard {} decoded", s.shard);
                }
            }
        }
    }

    #[test]
    fn disaggregated_ttft_includes_prefill_and_transfer() {
        // A handed-off request's TTFT spans prefill-shard queueing +
        // prefill + KV transfer + decode admission: it must exceed its
        // intrinsic prefill cost, and its end-to-end accounting must be
        // internally consistent.
        let mut c = build(ClusterSpec::disaggregated(1, 1, 1));
        c.submit(Request::new(0, vec![7; 64], 3));
        let rep = c.run_to_completion().unwrap();
        let r = &rep.results[0];
        assert_eq!(r.tokens.len(), 3);
        assert!(r.sim_ttft_ns > 0.0);
        assert!(r.ttft_ns() > r.sim_ttft_ns, "TTFT must include the KV transfer");
        assert!(r.e2e_ns() > r.ttft_ns());
        let kv: f64 = rep.shards.iter().map(|s| s.kv_transfer_ns).sum();
        let expected = tiny_spec().kv_cache_bytes(64) as f64 / 64.0;
        assert!((kv - expected).abs() < 1e-6, "kv {kv} vs expected {expected}");
    }

    #[test]
    fn kv_link_serializes_concurrent_transfers() {
        // Two identical prompts on two identical prefill shards finish
        // prefill at the same simulated instant; the shared link carries
        // them one after the other, so the second transfer is charged
        // queueing + wire time (2×), not a second full-bandwidth lane.
        let mut c = build(ClusterSpec::disaggregated(2, 2, 1));
        c.submit(Request::new(0, vec![1; 64], 2));
        c.submit(Request::new(1, vec![1; 64], 2));
        let rep = c.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 2);
        let wire = tiny_spec().kv_cache_bytes(64) as f64 / 64.0;
        let kv: f64 = rep.shards.iter().map(|s| s.kv_transfer_ns).sum();
        assert!(
            (kv - 3.0 * wire).abs() < 1e-6,
            "kv {kv} vs wire {wire}: second transfer must queue behind the first (expect 3×)"
        );
    }

    #[test]
    fn zero_token_requests_complete_on_the_prefill_shard() {
        // Nothing to decode ⇒ nothing to hand off: the prefill shard
        // retires the request itself and no KV transfer is charged.
        let mut c = build(ClusterSpec::disaggregated(1, 1, 2));
        c.submit(Request::new(0, vec![1, 2, 3], 0));
        c.submit(Request::new(1, vec![2, 2], 2));
        let rep = c.run_to_completion().unwrap();
        assert_eq!(rep.results.len(), 2);
        assert!(rep.results[0].tokens.is_empty());
        assert_eq!(rep.results[1].tokens.len(), 2);
        let sent: usize = rep
            .shards
            .iter()
            .filter(|s| s.role == ShardRole::Prefill)
            .map(|s| s.handoffs)
            .sum();
        assert_eq!(sent, 1, "only the decoding request crosses the link");
    }
}
