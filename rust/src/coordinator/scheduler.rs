//! Admission scheduling policy for the serving loop.
//!
//! A [`Scheduler`] owns the pending-request queue of one worker shard and
//! decides which requests fill freed batch slots between decode
//! iterations.  [`super::FcfsBatcher`] is the first-come-first-served
//! implementation (the paper's setting); the trait exists so priority,
//! deadline-aware or length-bucketed policies plug in without touching the
//! server loop.

use super::server::Request;

pub trait Scheduler: Send {
    /// Enqueue a request.
    fn submit(&mut self, req: Request);

    /// Requests waiting for admission.
    fn pending(&self) -> usize;

    /// Hand out up to `slots` requests, in policy order.  The server calls
    /// this once per decode iteration with the free batch slots.
    ///
    /// **Contract:** when `slots > 0` and `pending() > 0`, at least one
    /// request must be returned.  `Server::run_to_completion` drains the
    /// queue in a loop with no clock, so a policy that withholds queued
    /// work (e.g. waiting on a deadline) would otherwise spin forever —
    /// the server detects a withholding scheduler and errors out.
    /// Time-based admission belongs in the async intake planned on the
    /// ROADMAP, not in this synchronous drain.
    fn next_batch(&mut self, slots: usize) -> Vec<Request>;
}
