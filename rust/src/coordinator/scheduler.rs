//! Admission scheduling policies for the serving loop.
//!
//! A [`Scheduler`] owns the pending-request queue of one worker shard and
//! decides which requests fill freed batch slots between decode
//! iterations.  Three policies ship today:
//!
//! * [`super::FcfsBatcher`] — first-come-first-served (the paper's
//!   setting).
//! * [`LengthBucketed`] — groups pending requests by prompt-length bucket
//!   (the [`super::batcher::ctx_bucket`] boundaries shared with the
//!   server's cost caches) and admits from one bucket at a time, so batch
//!   members have similar lengths and the lockstep decode iteration is not
//!   gated by one long-context straggler.
//! * [`EdfScheduler`] — earliest-deadline-first over
//!   [`Request::deadline_ns`]; requests without a deadline run last.
//!
//! Time-based *visibility* (a request arriving later on the simulated
//! clock) is handled by the server's future-arrival queue, not here: a
//! scheduler only ever holds requests that have already arrived, so every
//! policy can honour the no-withholding contract below.

use super::batcher::ctx_bucket;
use super::server::Request;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

/// A scheduler's verdict on one running request, consulted by the serving
/// loop once per iteration when the active
/// [`ServingPolicy`](crate::config::ServingPolicy) enables preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preemption {
    /// Leave the request in the batch (the default for every policy).
    Keep,
    /// Abort the request and return it to the pending queue for
    /// re-admission.  Generation state is discarded — re-admission
    /// re-prefills the prompt, modelling recompute-style preemption where
    /// the KV cache is dropped to free the slot.
    ///
    /// **Contract:** a policy must eventually stop re-queueing a request
    /// (e.g. by keying on simulated time or attempt count).  The serving
    /// loop tolerates a short streak of rounds in which everything
    /// admitted is immediately re-queued, then errors out — like a
    /// `next_batch` implementation that withholds work.
    Requeue,
    /// Abort the request and retire it immediately as *shed*: it keeps the
    /// tokens generated so far, counts as missing its deadline, and frees
    /// its batch slot.  Overload sheds past-deadline work instead of
    /// dragging every other request's tail.
    Shed,
}

pub trait Scheduler: Send {
    /// Enqueue a request (already arrived on the simulated clock).
    fn submit(&mut self, req: Request);

    /// Requests waiting for admission.
    fn pending(&self) -> usize;

    /// Hand out up to `slots` requests, in policy order.  The server calls
    /// this once per decode iteration with the free batch slots.
    ///
    /// **Contract:** when `slots > 0` and `pending() > 0`, at least one
    /// request must be returned.  `Server::run_to_completion` drains the
    /// queue whenever the batch is empty, so a policy that withholds
    /// queued work would stall the clock — the server detects a
    /// withholding scheduler and errors out.
    ///
    /// **Contract (fast-forward):** a call that returns an empty batch
    /// (`slots == 0`, or nothing pending) must not mutate scheduler
    /// state.  The calendar engine elides such no-op calls inside a
    /// lockstep-decode stretch; a policy that needs to observe every
    /// iteration should implement [`Scheduler::should_preempt`] (and
    /// keep the default `preempt_horizon`), which forces per-iteration
    /// consultation.
    fn next_batch(&mut self, slots: usize) -> Vec<Request>;

    /// [`Scheduler::next_batch`] into a caller-owned buffer (appended,
    /// not cleared).  The serving loop calls this once per round with a
    /// recycled scratch vector; the default forwards to `next_batch`, so
    /// external policies keep working unchanged, while the in-tree
    /// policies override it to make admission allocation-free.  The
    /// admitted requests and their order must match `next_batch` exactly
    /// — engine bit-equivalence and the no-withholding contract both
    /// apply to this entry point too.
    fn next_batch_into(&mut self, slots: usize, out: &mut Vec<Request>) {
        out.extend(self.next_batch(slots));
    }

    /// Preemption hook: called once per serving-loop iteration for every
    /// running request — but only when the active serving policy sets
    /// `preempt = true` — with the tokens generated so far and the current
    /// simulated clock.  The default keeps everything (admission-only
    /// policies never preempt).
    fn should_preempt(&mut self, _req: &Request, _generated: usize, _sim_now_ns: f64) -> Preemption {
        Preemption::Keep
    }

    /// The earliest simulated time at which [`Scheduler::should_preempt`]
    /// might stop returning [`Preemption::Keep`] for this request — the
    /// *preemption horizon* the calendar engine fast-forwards to.
    ///
    /// Returning `Some(t)` is a promise with two parts: (a) `should_preempt`
    /// returns `Keep` for this request at every simulated time `<= t`, and
    /// (b) `should_preempt` is *pure* for this request — it mutates no
    /// scheduler state, so skipping the per-iteration calls inside a
    /// lockstep-decode stretch is unobservable.  A policy whose verdict
    /// never changes returns `Some(f64::INFINITY)`.
    ///
    /// The default `None` means "consult me every iteration": the calendar
    /// engine then steps decode one iteration at a time (exactly like the
    /// oracle), so stateful policies — e.g. ones keyed on attempt counts —
    /// stay correct without implementing this hook.
    fn preempt_horizon(&self, _req: &Request, _generated: usize) -> Option<f64> {
        None
    }

    /// Drain every pending request into `out`, in policy order — the
    /// crash-evacuation path (see `docs/robustness.md`).  The default
    /// drains through repeated [`Scheduler::next_batch_into`] calls,
    /// which is lossless for any policy honouring the no-withholding
    /// contract; the loop stops early (rather than spinning) if a policy
    /// violates it.
    fn drain_pending_into(&mut self, out: &mut Vec<Request>) {
        while self.pending() > 0 {
            let before = out.len();
            let slots = self.pending();
            self.next_batch_into(slots, out);
            if out.len() == before {
                break;
            }
        }
    }
}

/// Boxed schedulers forward, so heterogeneous clusters (per-group policies
/// chosen at runtime from a [`crate::config::SchedulerKind`]) can share one
/// `Coordinator<E, Box<dyn Scheduler>>` type.
impl Scheduler for Box<dyn Scheduler> {
    fn submit(&mut self, req: Request) {
        (**self).submit(req)
    }

    fn pending(&self) -> usize {
        (**self).pending()
    }

    fn next_batch(&mut self, slots: usize) -> Vec<Request> {
        (**self).next_batch(slots)
    }

    fn next_batch_into(&mut self, slots: usize, out: &mut Vec<Request>) {
        (**self).next_batch_into(slots, out)
    }

    fn should_preempt(&mut self, req: &Request, generated: usize, sim_now_ns: f64) -> Preemption {
        (**self).should_preempt(req, generated, sim_now_ns)
    }

    fn preempt_horizon(&self, req: &Request, generated: usize) -> Option<f64> {
        (**self).preempt_horizon(req, generated)
    }

    fn drain_pending_into(&mut self, out: &mut Vec<Request>) {
        (**self).drain_pending_into(out)
    }
}

/// Length-bucketed admission: pending requests are grouped by the
/// [`ctx_bucket`] of their prompt length, and each `next_batch` call
/// drains from the single bucket whose head request is oldest — batches
/// stay length-homogeneous while no bucket starves (the oldest head wins,
/// so every bucket eventually reaches the front).
#[derive(Debug, Default)]
pub struct LengthBucketed {
    buckets: BTreeMap<u64, VecDeque<(u64, Request)>>,
    pending: usize,
    seq: u64,
}

impl LengthBucketed {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket boundaries currently holding pending requests (for tests
    /// and introspection).
    pub fn occupied_buckets(&self) -> Vec<u64> {
        self.buckets.iter().filter(|(_, q)| !q.is_empty()).map(|(b, _)| *b).collect()
    }
}

impl Scheduler for LengthBucketed {
    fn submit(&mut self, req: Request) {
        let bucket = ctx_bucket(req.prompt.len() as u64);
        self.buckets.entry(bucket).or_default().push_back((self.seq, req));
        self.seq += 1;
        self.pending += 1;
    }

    fn pending(&self) -> usize {
        self.pending
    }

    fn preempt_horizon(&self, _req: &Request, _generated: usize) -> Option<f64> {
        // Admission-only policy: the default `should_preempt` keeps
        // everything forever and touches no state.
        Some(f64::INFINITY)
    }

    fn next_batch(&mut self, slots: usize) -> Vec<Request> {
        let mut out = Vec::new();
        self.next_batch_into(slots, &mut out);
        out
    }

    fn next_batch_into(&mut self, slots: usize, out: &mut Vec<Request>) {
        if slots == 0 || self.pending == 0 {
            return;
        }
        // The bucket whose head request has waited longest (lowest head
        // sequence); an explicit scan keeps the non-empty invariant out
        // of any panicking call.
        let mut best: Option<(u64, u64)> = None;
        for (b, q) in &self.buckets {
            if let Some((seq, _)) = q.front() {
                let lower = match best {
                    Some((s, _)) => *seq < s,
                    None => true,
                };
                if lower {
                    best = Some((*seq, *b));
                }
            }
        }
        let Some((_, bucket)) = best else { return };
        let Some(queue) = self.buckets.get_mut(&bucket) else { return };
        let take = slots.min(queue.len());
        out.extend(queue.drain(..take).map(|(_, r)| r));
        if queue.is_empty() {
            self.buckets.remove(&bucket);
        }
        self.pending -= take;
    }
}

/// Earliest-deadline-first entry; ordered by (deadline, submission seq) so
/// ties and deadline-free requests resolve deterministically.
#[derive(Debug, PartialEq, Eq)]
struct EdfEntry {
    deadline_ns: u64,
    seq: u64,
    req: Request,
}

impl Ord for EdfEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.deadline_ns, self.seq).cmp(&(other.deadline_ns, other.seq))
    }
}

impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deadline-aware admission: the pending request with the earliest
/// [`Request::deadline_ns`] is admitted first; requests without a deadline
/// sort after every deadlined one (treated as deadline = `u64::MAX`), and
/// FCFS order breaks ties.
///
/// Under a preemption-enabled [`ServingPolicy`](crate::config::ServingPolicy),
/// EDF also *sheds* running requests whose deadline has already passed on
/// the simulated clock ([`Preemption::Shed`]): a past-deadline request can
/// no longer meet its SLO, so every further decode iteration it occupies
/// only drags the tail of requests that still can.  Requests without a
/// deadline are never preempted.
#[derive(Debug, Default)]
pub struct EdfScheduler {
    heap: BinaryHeap<Reverse<EdfEntry>>,
    seq: u64,
}

impl EdfScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for EdfScheduler {
    fn submit(&mut self, req: Request) {
        let deadline_ns = req.deadline_ns.unwrap_or(u64::MAX);
        self.heap.push(Reverse(EdfEntry { deadline_ns, seq: self.seq, req }));
        self.seq += 1;
    }

    fn pending(&self) -> usize {
        self.heap.len()
    }

    fn next_batch(&mut self, slots: usize) -> Vec<Request> {
        let mut out = Vec::new();
        self.next_batch_into(slots, &mut out);
        out
    }

    fn next_batch_into(&mut self, slots: usize, out: &mut Vec<Request>) {
        let take = slots.min(self.heap.len());
        for _ in 0..take {
            let Some(entry) = self.heap.pop() else { break };
            out.push(entry.0.req);
        }
    }

    fn should_preempt(&mut self, req: &Request, generated: usize, sim_now_ns: f64) -> Preemption {
        match req.deadline_ns {
            // Finished requests retire on their own; shed only work that
            // is both past its deadline and still incomplete.
            Some(d) if (d as f64) < sim_now_ns && generated < req.max_new_tokens => {
                Preemption::Shed
            }
            _ => Preemption::Keep,
        }
    }

    fn preempt_horizon(&self, req: &Request, generated: usize) -> Option<f64> {
        // `should_preempt` is pure and keeps the request at every time up
        // to (and including) its deadline; deadline-free or budget-complete
        // requests are never shed, so their verdict never changes.
        match req.deadline_ns {
            Some(d) if generated < req.max_new_tokens => Some(d as f64),
            _ => Some(f64::INFINITY),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BUCKET_TOKENS;

    fn req(id: u64, prompt_len: usize) -> Request {
        Request::new(id, vec![1; prompt_len], 4)
    }

    #[test]
    fn length_bucketed_groups_similar_lengths() {
        let mut s = LengthBucketed::new();
        s.submit(req(0, 4)); // bucket 256
        s.submit(req(1, 400)); // bucket 512
        s.submit(req(2, 8)); // bucket 256
        s.submit(req(3, 500)); // bucket 512
        assert_eq!(s.occupied_buckets(), vec![BUCKET_TOKENS, 2 * BUCKET_TOKENS]);

        // Oldest head is request 0 (bucket 256): the whole first batch
        // comes from that bucket even though 1 arrived before 2.
        let first = s.next_batch(2);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        let second = s.next_batch(2);
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn length_bucketed_never_starves_a_bucket() {
        let mut s = LengthBucketed::new();
        s.submit(req(0, 300)); // long bucket, oldest
        for id in 1..5 {
            s.submit(req(id, 4)); // stream of short requests
        }
        // The long request's bucket has the oldest head, so it goes first
        // despite the short queue being deeper.
        let batch = s.next_batch(2);
        assert_eq!(batch[0].id, 0);
    }

    #[test]
    fn length_bucketed_honours_contract() {
        let mut s = LengthBucketed::new();
        s.submit(req(9, 10));
        assert!(s.next_batch(0).is_empty());
        assert_eq!(s.next_batch(4).len(), 1, "pending work + free slots must admit");
        assert!(s.next_batch(4).is_empty());
    }

    #[test]
    fn edf_orders_by_deadline_then_fcfs() {
        let mut s = EdfScheduler::new();
        s.submit(req(0, 2)); // no deadline → last
        s.submit(Request::new(1, vec![1], 4).with_deadline(500));
        s.submit(Request::new(2, vec![1], 4).with_deadline(100));
        s.submit(Request::new(3, vec![1], 4).with_deadline(500));
        let order: Vec<u64> = s.next_batch(4).iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 1, 3, 0]);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn edf_respects_slots() {
        let mut s = EdfScheduler::new();
        for id in 0..5 {
            s.submit(Request::new(id, vec![1], 1).with_deadline(1000 - id));
        }
        assert_eq!(s.next_batch(2).len(), 2);
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn edf_sheds_only_past_deadline_incomplete_requests() {
        let mut s = EdfScheduler::new();
        let live = Request::new(0, vec![1], 4).with_deadline(1_000);
        let dead = Request::new(1, vec![1], 4).with_deadline(100);
        let free = Request::new(2, vec![1], 4); // no deadline: never shed
        assert_eq!(s.should_preempt(&live, 1, 500.0), Preemption::Keep);
        assert_eq!(s.should_preempt(&dead, 1, 500.0), Preemption::Shed);
        assert_eq!(s.should_preempt(&free, 1, 500.0), Preemption::Keep);
        // A request that already generated its full budget retires on its
        // own this iteration — no point shedding it.
        assert_eq!(s.should_preempt(&dead, 4, 500.0), Preemption::Keep);
        // At the deadline instant (not past it), the request still counts.
        assert_eq!(s.should_preempt(&dead, 1, 100.0), Preemption::Keep);
    }

    #[test]
    fn preempt_horizons_match_the_verdict_stream() {
        // EDF: the horizon is the deadline — Keep at every time <= d, and
        // the verdict may flip only strictly past it.
        let edf = EdfScheduler::new();
        let dead = Request::new(0, vec![1], 4).with_deadline(100);
        assert_eq!(edf.preempt_horizon(&dead, 1), Some(100.0));
        // Budget-complete or deadline-free requests are never shed.
        assert_eq!(edf.preempt_horizon(&dead, 4), Some(f64::INFINITY));
        let free = Request::new(1, vec![1], 4);
        assert_eq!(edf.preempt_horizon(&free, 0), Some(f64::INFINITY));
        // Admission-only policies promise an infinite horizon.
        let fcfs = crate::coordinator::FcfsBatcher::new(2);
        assert_eq!(fcfs.preempt_horizon(&dead, 0), Some(f64::INFINITY));
        let lb = LengthBucketed::new();
        assert_eq!(lb.preempt_horizon(&dead, 0), Some(f64::INFINITY));
        // Boxed schedulers forward the hook.
        let boxed: Box<dyn Scheduler> = Box::new(EdfScheduler::new());
        assert_eq!(boxed.preempt_horizon(&dead, 1), Some(100.0));
    }

    #[test]
    fn drain_pending_into_is_lossless_for_every_policy() {
        // Crash evacuation drains through next_batch_into: every pending
        // request must come back exactly once, whatever the policy.
        let mut edf = EdfScheduler::new();
        for id in 0..5 {
            edf.submit(Request::new(id, vec![1], 1).with_deadline(1000 - id));
        }
        let mut out = Vec::new();
        edf.drain_pending_into(&mut out);
        assert_eq!(edf.pending(), 0);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);

        let mut lb = LengthBucketed::new();
        lb.submit(req(0, 4));
        lb.submit(req(1, 400));
        lb.submit(req(2, 8));
        let mut out = Vec::new();
        lb.drain_pending_into(&mut out);
        assert_eq!(lb.pending(), 0);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);

        let mut boxed: Box<dyn Scheduler> = Box::new(crate::coordinator::FcfsBatcher::new(2));
        boxed.submit(req(7, 4));
        let mut out = Vec::new();
        boxed.drain_pending_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(boxed.pending(), 0);
    }

    #[test]
    fn default_schedulers_never_preempt() {
        let dead = Request::new(0, vec![1], 4).with_deadline(1);
        let mut fcfs = crate::coordinator::FcfsBatcher::new(2);
        assert_eq!(fcfs.should_preempt(&dead, 0, 1e9), Preemption::Keep);
        let mut lb = LengthBucketed::new();
        assert_eq!(lb.should_preempt(&dead, 0, 1e9), Preemption::Keep);
    }
}
