//! Work-stealing executor for shard simulation.
//!
//! The coordinator used to pin one OS thread per shard: a 64-shard
//! cluster on a 4-core host serialized behind the scheduler, and a
//! 2-shard cluster left most cores idle.  This module runs a fixed pool
//! of workers (`--threads N`, default = available parallelism) over
//! *resumable* tasks: each task runs one bounded event batch per poll and
//! reports whether it has more work ([`Poll::Pending`]), is waiting on
//! external input ([`Poll::Blocked`] — e.g. an open live-intake channel
//! with nothing queued), or completed ([`Poll::Done`]).
//!
//! ## Scheduling
//!
//! Tasks are dealt round-robin across per-worker deques.  A worker pops
//! its own queue from the front (oldest first, so a single worker
//! round-robins its shards deterministically) and steals from the *back*
//! of its peers' queues when empty — the classic owner-LIFO/thief-FIFO
//! split, here with plain mutex-guarded deques (contention is one lock op
//! per event *batch*, thousands of simulated rounds, so a lock-free deque
//! would buy nothing measurable).  A task id lives in exactly one queue
//! at a time and its task body is taken out of its slot while running, so
//! no task ever runs on two workers concurrently.
//!
//! ## Determinism
//!
//! The executor adds no nondeterminism to simulated results: tasks
//! (shard serving runs) never communicate between coordinator barriers,
//! each task's own poll sequence is serial whatever worker runs it, and
//! results land in a slot indexed by task order — never completion
//! order.  The same task set therefore produces bit-identical outputs
//! for every thread count, which `tests/proptests.rs` and `exp scale`
//! pin via `ServerReport::sim_divergence`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What one poll of a resumable task reported.
pub enum Poll<T> {
    /// More event batches remain; reschedule the task.
    Pending,
    /// No progress possible until external input arrives (an open intake
    /// with an empty channel).  The task is rescheduled; workers back off
    /// when every live task is blocked instead of spinning.
    Blocked,
    /// The task completed with this result.
    Done(T),
}

/// A resumable unit of work: polled repeatedly until it returns
/// [`Poll::Done`].  Borrows are fine (`'a`): the pool runs under
/// `std::thread::scope`.
pub type Task<'a, T> = Box<dyn FnMut() -> Poll<T> + Send + 'a>;

/// The host's available parallelism (1 if it cannot be determined).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker-thread count: an explicit request wins
/// (floored at 1), otherwise the `RACAM_THREADS` environment variable
/// (how CI pins the equivalence suite to a 2-thread pool), otherwise the
/// host's available parallelism.
pub fn resolve_threads(requested: Option<usize>) -> usize {
    if let Some(n) = requested {
        return n.max(1);
    }
    if let Ok(v) = std::env::var("RACAM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    available_parallelism()
}

/// Host-side counters for one pool worker, measured on the wall clock
/// (unlike everything in a [`crate::coordinator::ServerReport`], these
/// are *not* deterministic — they describe the host run, not the
/// simulation, and feed `exp scale`, the Chrome-trace worker tracks, and
/// nothing that gates an equivalence check).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Task polls this worker executed (one per event batch).
    pub polls: u64,
    /// Polls whose task id was stolen from a peer's queue.
    pub steals: u64,
    /// Backoff sleeps taken because every live task reported
    /// [`Poll::Blocked`] (shards waiting on an open intake).
    pub blocked_streaks: u64,
    /// Backoff sleeps taken with nothing runnable (remaining tasks were
    /// mid-batch on other workers).
    pub idle_sleeps: u64,
    /// Wall time this worker spent in the pool, ns.
    pub wall_ns: u64,
}

impl WorkerStats {
    /// Accumulate another worker's counters (how the coordinator folds
    /// the per-wave stats of a disaggregated run into one row per
    /// worker).
    pub fn absorb(&mut self, other: &WorkerStats) {
        self.polls += other.polls;
        self.steals += other.steals;
        self.blocked_streaks += other.blocked_streaks;
        self.idle_sleeps += other.idle_sleeps;
        self.wall_ns += other.wall_ns;
    }

    /// Fraction of this worker's scheduling decisions that ended in an
    /// idle backoff sleep — the `exp scale` sweep's headline imbalance
    /// signal (0.0 when the worker never slept).
    pub fn idle_ratio(&self) -> f64 {
        let denom = self.polls + self.idle_sleeps;
        if denom == 0 {
            0.0
        } else {
            self.idle_sleeps as f64 / denom as f64
        }
    }
}

struct Shared<'a, T> {
    /// Task bodies, indexed by task id.  A body is taken out while it
    /// runs, so the lock never covers a poll.
    slots: Vec<Mutex<Option<Task<'a, T>>>>,
    /// Per-worker run queues of task ids.
    queues: Vec<Mutex<VecDeque<usize>>>,
    /// Completed results, indexed by task id (never completion order).
    results: Vec<Mutex<Option<T>>>,
    /// Tasks not yet [`Poll::Done`]; 0 is the pool shutdown signal.
    remaining: AtomicUsize,
}

/// Run `tasks` to completion on `threads` workers and return their
/// results **in task order**.  `threads` is clamped to `[1, tasks.len()]`
/// — extra workers would only spin.  With one worker the pool runs
/// inline on the calling thread (no spawn, honest single-thread wall
/// times for the `exp scale` sweep baseline).
///
/// Panics in a task propagate (the scope join re-raises), matching the
/// old thread-per-shard behavior under test assertions.
pub fn run_tasks<'a, T: Send>(threads: usize, tasks: Vec<Task<'a, T>>) -> Vec<T> {
    run_tasks_with_stats(threads, tasks).0
}

/// [`run_tasks`], also returning one [`WorkerStats`] per pool worker
/// (index = worker id).  The counters are observational only — they are
/// gathered in worker-local registers and written out once at pool
/// shutdown, so the instrumented pool schedules exactly like the
/// uninstrumented one did.
pub fn run_tasks_with_stats<'a, T: Send>(
    threads: usize,
    tasks: Vec<Task<'a, T>>,
) -> (Vec<T>, Vec<WorkerStats>) {
    let n = tasks.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let threads = threads.clamp(1, n);
    let shared = Shared {
        slots: tasks.into_iter().map(|t| Mutex::new(Some(t))).collect(),
        queues: (0..threads).map(|_| Mutex::new(VecDeque::with_capacity(n))).collect(),
        results: (0..n).map(|_| Mutex::new(None)).collect(),
        remaining: AtomicUsize::new(n),
    };
    // Deal tasks round-robin: the initial split is even, and ids stay
    // ascending within each queue.
    for tid in 0..n {
        shared.queues[tid % threads].lock().unwrap().push_back(tid);
    }
    let stats = if threads == 1 {
        vec![worker(&shared, 0)]
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|w| {
                    let shared = &shared;
                    scope.spawn(move || worker(shared, w))
                })
                .collect();
            // Joining inside the scope hands back each worker's stats;
            // a worker panic re-raises here, preserving the propagation
            // the tests rely on.
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    };
    let results = shared
        .results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("remaining hit 0 with every slot filled"))
        .collect();
    (results, stats)
}

fn worker<T: Send>(shared: &Shared<'_, T>, me: usize) -> WorkerStats {
    #[allow(clippy::disallowed_methods)] // executor-owned host timing (detcheck allowlist)
    let started = std::time::Instant::now();
    let mut stats = WorkerStats::default();
    let nq = shared.queues.len();
    let mut blocked_streak = 0usize;
    let mut idle_spins = 0usize;
    loop {
        if shared.remaining.load(Ordering::Acquire) == 0 {
            stats.wall_ns = started.elapsed().as_nanos() as u64;
            return stats;
        }
        // Own queue first (front = oldest), then steal from peers' backs.
        let mut stolen = false;
        let tid = shared.queues[me].lock().unwrap().pop_front().or_else(|| {
            let t = (1..nq).find_map(|d| shared.queues[(me + d) % nq].lock().unwrap().pop_back());
            stolen = t.is_some();
            t
        });
        let Some(tid) = tid else {
            // Nothing runnable: the remaining tasks are mid-batch on
            // other workers.  Yield first, then back off, so the tail of
            // a run does not burn a core per idle worker.
            idle_spins += 1;
            if idle_spins < 64 {
                std::thread::yield_now();
            } else {
                stats.idle_sleeps += 1;
                std::thread::sleep(Duration::from_micros(100));
            }
            continue;
        };
        idle_spins = 0;
        stats.polls += 1;
        if stolen {
            stats.steals += 1;
        }
        let mut task = shared.slots[tid]
            .lock()
            .unwrap()
            .take()
            .expect("a queued task id always has its body in its slot");
        match task() {
            Poll::Done(v) => {
                *shared.results[tid].lock().unwrap() = Some(v);
                shared.remaining.fetch_sub(1, Ordering::AcqRel);
                blocked_streak = 0;
            }
            Poll::Pending => {
                // Restore the body *before* re-queueing the id: an id is
                // only visible to thieves once its slot is occupied.
                *shared.slots[tid].lock().unwrap() = Some(task);
                shared.queues[me].lock().unwrap().push_back(tid);
                blocked_streak = 0;
            }
            Poll::Blocked => {
                *shared.slots[tid].lock().unwrap() = Some(task);
                shared.queues[me].lock().unwrap().push_back(tid);
                // When every live task reports blocked (all shards
                // waiting on an open intake), sleep instead of spinning
                // try_recv at full tilt.
                blocked_streak += 1;
                if blocked_streak >= shared.remaining.load(Ordering::Acquire).max(1) {
                    stats.blocked_streaks += 1;
                    std::thread::sleep(Duration::from_micros(200));
                    blocked_streak = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    /// A task that needs `total` polls, counting them.
    fn counting(total: usize) -> (std::sync::Arc<AtomicUsize>, Task<'static, usize>) {
        let polls = std::sync::Arc::new(AtomicUsize::new(0));
        let p = polls.clone();
        let mut left = total;
        let task: Task<'static, usize> = Box::new(move || {
            p.fetch_add(1, Ordering::Relaxed);
            left -= 1;
            if left == 0 {
                Poll::Done(total)
            } else {
                Poll::Pending
            }
        });
        (polls, task)
    }

    #[test]
    fn results_come_back_in_task_order() {
        for threads in [1, 2, 4, 8] {
            let tasks: Vec<Task<'_, usize>> = (0..16).map(|i| counting(i % 5 + 1).1).collect();
            let out = run_tasks(threads, tasks);
            let want: Vec<usize> = (0..16).map(|i| i % 5 + 1).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn every_task_is_polled_exactly_to_completion() {
        let (polls, task) = counting(7);
        assert_eq!(run_tasks(4, vec![task]), vec![7]);
        assert_eq!(polls.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn more_threads_than_tasks_is_fine() {
        let tasks: Vec<Task<'_, usize>> = (0..2).map(|_| counting(3).1).collect();
        assert_eq!(run_tasks(64, tasks), vec![3, 3]);
    }

    #[test]
    fn tasks_can_borrow_the_callers_data() {
        let mut cells = [0u64; 8];
        let tasks: Vec<Task<'_, ()>> = cells
            .iter_mut()
            .map(|c| {
                let mut rounds = 10;
                Box::new(move || {
                    *c += 1;
                    rounds -= 1;
                    if rounds == 0 {
                        Poll::Done(())
                    } else {
                        Poll::Pending
                    }
                }) as Task<'_, ()>
            })
            .collect();
        run_tasks(3, tasks);
        assert_eq!(cells, [10; 8]);
    }

    #[test]
    fn blocked_tasks_are_repolled_until_unblocked() {
        // Task 0 blocks until task 1 (running on any worker) flips the
        // flag — exercises the re-queue + backoff path.
        let flag = AtomicBool::new(false);
        let mut t1_rounds = 50;
        let tasks: Vec<Task<'_, u32>> = vec![
            Box::new(|| if flag.load(Ordering::Acquire) { Poll::Done(1) } else { Poll::Blocked }),
            Box::new(|| {
                t1_rounds -= 1;
                if t1_rounds == 0 {
                    flag.store(true, Ordering::Release);
                    Poll::Done(2)
                } else {
                    Poll::Pending
                }
            }),
        ];
        assert_eq!(run_tasks(2, tasks), vec![1, 2]);
    }

    #[test]
    fn worker_stats_count_every_poll_and_no_steals_single_threaded() {
        let tasks: Vec<Task<'_, usize>> = (0..4).map(|_| counting(5).1).collect();
        let (out, stats) = run_tasks_with_stats(1, tasks);
        assert_eq!(out, vec![5; 4]);
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].polls, 20, "one poll per event batch");
        assert_eq!(stats[0].steals, 0, "a lone worker has no one to steal from");
        assert!(stats[0].wall_ns > 0);
    }

    #[test]
    fn worker_stats_polls_sum_across_the_pool() {
        for threads in [2, 4] {
            let tasks: Vec<Task<'_, usize>> = (0..8).map(|_| counting(9).1).collect();
            let (out, stats) = run_tasks_with_stats(threads, tasks);
            assert_eq!(out, vec![9; 8]);
            assert_eq!(stats.len(), threads);
            let polls: u64 = stats.iter().map(|s| s.polls).sum();
            assert_eq!(polls, 72, "threads={threads}: every poll lands in exactly one worker");
        }
    }

    #[test]
    fn worker_stats_absorb_and_idle_ratio() {
        let mut a = WorkerStats { polls: 6, steals: 1, blocked_streaks: 0, idle_sleeps: 2, wall_ns: 10 };
        let b = WorkerStats { polls: 4, steals: 2, blocked_streaks: 3, idle_sleeps: 0, wall_ns: 5 };
        a.absorb(&b);
        assert_eq!(
            a,
            WorkerStats { polls: 10, steals: 3, blocked_streaks: 3, idle_sleeps: 2, wall_ns: 15 }
        );
        assert!((a.idle_ratio() - 2.0 / 12.0).abs() < 1e-12);
        assert_eq!(WorkerStats::default().idle_ratio(), 0.0, "empty stats divide safely");
    }

    #[test]
    fn resolve_threads_prefers_explicit_over_env_over_host() {
        assert_eq!(resolve_threads(Some(3)), 3);
        assert_eq!(resolve_threads(Some(0)), 1, "explicit requests floor at 1");
        assert!(resolve_threads(None) >= 1);
    }
}
