//! Work-stealing executor for shard simulation.
//!
//! The coordinator used to pin one OS thread per shard: a 64-shard
//! cluster on a 4-core host serialized behind the scheduler, and a
//! 2-shard cluster left most cores idle.  This module runs a fixed pool
//! of workers (`--threads N`, default = available parallelism) over
//! *resumable* tasks: each task runs one bounded event batch per poll and
//! reports whether it has more work ([`Poll::Pending`]), is waiting on
//! external input ([`Poll::Blocked`] — e.g. an open live-intake channel
//! with nothing queued), or completed ([`Poll::Done`]).
//!
//! ## Scheduling
//!
//! Tasks are dealt round-robin across per-worker deques.  A worker pops
//! its own queue from the front (oldest first, so a single worker
//! round-robins its shards deterministically) and steals from the *back*
//! of its peers' queues when empty — the classic owner-LIFO/thief-FIFO
//! split, here with plain mutex-guarded deques (contention is one lock op
//! per event *batch*, thousands of simulated rounds, so a lock-free deque
//! would buy nothing measurable).  A task id lives in exactly one queue
//! at a time and its task body is taken out of its slot while running, so
//! no task ever runs on two workers concurrently.
//!
//! ## Determinism
//!
//! The executor adds no nondeterminism to simulated results: tasks
//! (shard serving runs) never communicate between coordinator barriers,
//! each task's own poll sequence is serial whatever worker runs it, and
//! results land in a slot indexed by task order — never completion
//! order.  The same task set therefore produces bit-identical outputs
//! for every thread count, which `tests/proptests.rs` and `exp scale`
//! pin via `ServerReport::sim_divergence`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What one poll of a resumable task reported.
pub enum Poll<T> {
    /// More event batches remain; reschedule the task.
    Pending,
    /// No progress possible until external input arrives (an open intake
    /// with an empty channel).  The task is rescheduled; workers back off
    /// when every live task is blocked instead of spinning.
    Blocked,
    /// The task completed with this result.
    Done(T),
}

/// A resumable unit of work: polled repeatedly until it returns
/// [`Poll::Done`].  Borrows are fine (`'a`): the pool runs under
/// `std::thread::scope`.
pub type Task<'a, T> = Box<dyn FnMut() -> Poll<T> + Send + 'a>;

/// The host's available parallelism (1 if it cannot be determined).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker-thread count: an explicit request wins
/// (floored at 1), otherwise the `RACAM_THREADS` environment variable
/// (how CI pins the equivalence suite to a 2-thread pool), otherwise the
/// host's available parallelism.
pub fn resolve_threads(requested: Option<usize>) -> usize {
    if let Some(n) = requested {
        return n.max(1);
    }
    if let Ok(v) = std::env::var("RACAM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    available_parallelism()
}

struct Shared<'a, T> {
    /// Task bodies, indexed by task id.  A body is taken out while it
    /// runs, so the lock never covers a poll.
    slots: Vec<Mutex<Option<Task<'a, T>>>>,
    /// Per-worker run queues of task ids.
    queues: Vec<Mutex<VecDeque<usize>>>,
    /// Completed results, indexed by task id (never completion order).
    results: Vec<Mutex<Option<T>>>,
    /// Tasks not yet [`Poll::Done`]; 0 is the pool shutdown signal.
    remaining: AtomicUsize,
}

/// Run `tasks` to completion on `threads` workers and return their
/// results **in task order**.  `threads` is clamped to `[1, tasks.len()]`
/// — extra workers would only spin.  With one worker the pool runs
/// inline on the calling thread (no spawn, honest single-thread wall
/// times for the `exp scale` sweep baseline).
///
/// Panics in a task propagate (the scope join re-raises), matching the
/// old thread-per-shard behavior under test assertions.
pub fn run_tasks<'a, T: Send>(threads: usize, tasks: Vec<Task<'a, T>>) -> Vec<T> {
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let shared = Shared {
        slots: tasks.into_iter().map(|t| Mutex::new(Some(t))).collect(),
        queues: (0..threads).map(|_| Mutex::new(VecDeque::with_capacity(n))).collect(),
        results: (0..n).map(|_| Mutex::new(None)).collect(),
        remaining: AtomicUsize::new(n),
    };
    // Deal tasks round-robin: the initial split is even, and ids stay
    // ascending within each queue.
    for tid in 0..n {
        shared.queues[tid % threads].lock().unwrap().push_back(tid);
    }
    if threads == 1 {
        worker(&shared, 0);
    } else {
        std::thread::scope(|scope| {
            for w in 0..threads {
                let shared = &shared;
                scope.spawn(move || worker(shared, w));
            }
        });
    }
    shared
        .results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("remaining hit 0 with every slot filled"))
        .collect()
}

fn worker<T: Send>(shared: &Shared<'_, T>, me: usize) {
    let nq = shared.queues.len();
    let mut blocked_streak = 0usize;
    let mut idle_spins = 0usize;
    loop {
        if shared.remaining.load(Ordering::Acquire) == 0 {
            return;
        }
        // Own queue first (front = oldest), then steal from peers' backs.
        let tid = shared.queues[me].lock().unwrap().pop_front().or_else(|| {
            (1..nq).find_map(|d| shared.queues[(me + d) % nq].lock().unwrap().pop_back())
        });
        let Some(tid) = tid else {
            // Nothing runnable: the remaining tasks are mid-batch on
            // other workers.  Yield first, then back off, so the tail of
            // a run does not burn a core per idle worker.
            idle_spins += 1;
            if idle_spins < 64 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(Duration::from_micros(100));
            }
            continue;
        };
        idle_spins = 0;
        let mut task = shared.slots[tid]
            .lock()
            .unwrap()
            .take()
            .expect("a queued task id always has its body in its slot");
        match task() {
            Poll::Done(v) => {
                *shared.results[tid].lock().unwrap() = Some(v);
                shared.remaining.fetch_sub(1, Ordering::AcqRel);
                blocked_streak = 0;
            }
            Poll::Pending => {
                // Restore the body *before* re-queueing the id: an id is
                // only visible to thieves once its slot is occupied.
                *shared.slots[tid].lock().unwrap() = Some(task);
                shared.queues[me].lock().unwrap().push_back(tid);
                blocked_streak = 0;
            }
            Poll::Blocked => {
                *shared.slots[tid].lock().unwrap() = Some(task);
                shared.queues[me].lock().unwrap().push_back(tid);
                // When every live task reports blocked (all shards
                // waiting on an open intake), sleep instead of spinning
                // try_recv at full tilt.
                blocked_streak += 1;
                if blocked_streak >= shared.remaining.load(Ordering::Acquire).max(1) {
                    std::thread::sleep(Duration::from_micros(200));
                    blocked_streak = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    /// A task that needs `total` polls, counting them.
    fn counting(total: usize) -> (std::sync::Arc<AtomicUsize>, Task<'static, usize>) {
        let polls = std::sync::Arc::new(AtomicUsize::new(0));
        let p = polls.clone();
        let mut left = total;
        let task: Task<'static, usize> = Box::new(move || {
            p.fetch_add(1, Ordering::Relaxed);
            left -= 1;
            if left == 0 {
                Poll::Done(total)
            } else {
                Poll::Pending
            }
        });
        (polls, task)
    }

    #[test]
    fn results_come_back_in_task_order() {
        for threads in [1, 2, 4, 8] {
            let tasks: Vec<Task<'_, usize>> = (0..16).map(|i| counting(i % 5 + 1).1).collect();
            let out = run_tasks(threads, tasks);
            let want: Vec<usize> = (0..16).map(|i| i % 5 + 1).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn every_task_is_polled_exactly_to_completion() {
        let (polls, task) = counting(7);
        assert_eq!(run_tasks(4, vec![task]), vec![7]);
        assert_eq!(polls.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn more_threads_than_tasks_is_fine() {
        let tasks: Vec<Task<'_, usize>> = (0..2).map(|_| counting(3).1).collect();
        assert_eq!(run_tasks(64, tasks), vec![3, 3]);
    }

    #[test]
    fn tasks_can_borrow_the_callers_data() {
        let mut cells = [0u64; 8];
        let tasks: Vec<Task<'_, ()>> = cells
            .iter_mut()
            .map(|c| {
                let mut rounds = 10;
                Box::new(move || {
                    *c += 1;
                    rounds -= 1;
                    if rounds == 0 {
                        Poll::Done(())
                    } else {
                        Poll::Pending
                    }
                }) as Task<'_, ()>
            })
            .collect();
        run_tasks(3, tasks);
        assert_eq!(cells, [10; 8]);
    }

    #[test]
    fn blocked_tasks_are_repolled_until_unblocked() {
        // Task 0 blocks until task 1 (running on any worker) flips the
        // flag — exercises the re-queue + backoff path.
        let flag = AtomicBool::new(false);
        let mut t1_rounds = 50;
        let tasks: Vec<Task<'_, u32>> = vec![
            Box::new(|| if flag.load(Ordering::Acquire) { Poll::Done(1) } else { Poll::Blocked }),
            Box::new(|| {
                t1_rounds -= 1;
                if t1_rounds == 0 {
                    flag.store(true, Ordering::Release);
                    Poll::Done(2)
                } else {
                    Poll::Pending
                }
            }),
        ];
        assert_eq!(run_tasks(2, tasks), vec![1, 2]);
    }

    #[test]
    fn resolve_threads_prefers_explicit_over_env_over_host() {
        assert_eq!(resolve_threads(Some(3)), 3);
        assert_eq!(resolve_threads(Some(0)), 1, "explicit requests floor at 1");
        assert!(resolve_threads(None) >= 1);
    }
}
