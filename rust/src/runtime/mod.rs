//! Host runtime: the work-stealing shard [`executor`], host-process
//! measurement helpers, and the PJRT artifact path.
//!
//! The PJRT side loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the JAX/Pallas compile path) and executes
//! them on the XLA CPU client.  This is the only place Python-authored
//! compute enters the Rust request path — as compiled HLO, never as Python.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects, while the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT client itself ([`Runtime`] / [`LoadedModule`]) requires the
//! `xla` bindings and is gated behind the `pjrt` cargo feature; artifact
//! discovery ([`ArtifactSet`]) is always available so build tooling and the
//! CLI can report what is (not) present.

mod artifact;
pub mod executor;

pub use artifact::{artifacts_dir, ArtifactSet};

/// Peak resident set size (`VmHWM`) of this process in bytes, read from
/// `/proc/self/status` — the high-water mark since process start, so
/// successive readings are monotone.  `None` where the platform does not
/// expose it (non-Linux builds compile the procfs read out entirely, and
/// a Linux host with a masked or malformed `/proc` degrades the same
/// way); callers render `-`/absent rather than a fabricated 0.
pub fn peak_rss_bytes() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        parse_vm_hwm(&std::fs::read_to_string("/proc/self/status").ok()?)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Extract `VmHWM` (kB → bytes) from `/proc/self/status` text.  Split
/// out of [`peak_rss_bytes`] so the parsing — including its rejection of
/// malformed lines — is unit-testable on any platform.
fn parse_vm_hwm(status: &str) -> Option<u64> {
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vm_hwm_parses_a_proc_status_excerpt() {
        let status = "Name:\tracam\nVmPeak:\t  123 kB\nVmHWM:\t   2048 kB\nVmRSS:\t 1024 kB\n";
        assert_eq!(parse_vm_hwm(status), Some(2048 * 1024));
    }

    #[test]
    fn vm_hwm_rejects_missing_or_malformed_lines() {
        assert_eq!(parse_vm_hwm(""), None);
        assert_eq!(parse_vm_hwm("Name:\tracam\nVmRSS:\t 1024 kB\n"), None, "no VmHWM line");
        assert_eq!(parse_vm_hwm("VmHWM:\n"), None, "no value field");
        assert_eq!(parse_vm_hwm("VmHWM:\tlots kB\n"), None, "non-numeric value");
    }

    #[test]
    fn peak_rss_reports_something_plausible_on_linux() {
        match peak_rss_bytes() {
            // A live process has touched at least a page; VmHWM is in kB
            // so the floor is 1024 bytes.
            Some(bytes) => assert!(bytes >= 1024, "implausible peak RSS {bytes}"),
            // Non-Linux (or masked /proc): graceful absence is the contract.
            None => {}
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_client {
    use crate::Result;
    use anyhow::Context;
    use std::path::Path;

    /// A PJRT client + the modules loaded on it.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One compiled executable.
    pub struct LoadedModule {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Runtime {
        /// CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            Ok(Runtime { client: xla::PjRtClient::cpu()? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModule> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).context("PJRT compile")?;
            let name =
                path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
            Ok(LoadedModule { name, exe })
        }
    }

    impl LoadedModule {
        /// Execute with int32 inputs, returning the flattened int32 output.
        ///
        /// The AOT pipeline lowers with `return_tuple=True`, so every artifact
        /// yields a 1-tuple.
        pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
            let lits = self.literals_i32(inputs)?;
            let out = self.execute(&lits)?;
            Ok(out.to_vec::<i32>()?)
        }

        /// Execute with f32 inputs, returning the flattened f32 output.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                lits.push(xla::Literal::vec1(data).reshape(dims)?);
            }
            let out = self.execute(&lits)?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Execute with pre-built literals (mixed input dtypes), returning the
        /// unwrapped 1-tuple output literal.
        pub fn run_literals(&self, lits: &[xla::Literal]) -> Result<xla::Literal> {
            self.execute(lits)
        }

        fn literals_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<xla::Literal>> {
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                lits.push(xla::Literal::vec1(data).reshape(dims)?);
            }
            Ok(lits)
        }

        fn execute(&self, lits: &[xla::Literal]) -> Result<xla::Literal> {
            let result = self.exe.execute::<xla::Literal>(lits)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple1()?)
        }
    }

    #[cfg(test)]
    mod tests {
        // Runtime execution is covered by the integration tests in
        // rust/tests/ (they require `make artifacts` to have run); here we
        // only check client construction, which needs no artifacts.
        use super::*;

        #[test]
        fn cpu_client_comes_up() {
            let rt = Runtime::cpu().expect("PJRT CPU client");
            assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_client::{LoadedModule, Runtime};
