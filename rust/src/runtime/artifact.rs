//! Artifact discovery: names and locations of the AOT outputs the compile
//! path (`python/compile/aot.py`) produces.

use std::path::PathBuf;

/// Locate the artifacts directory: `$RACAM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("RACAM_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// The artifact set `aot.py` emits.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
}

impl ArtifactSet {
    pub fn discover() -> Self {
        ArtifactSet { dir: artifacts_dir() }
    }

    /// int32 GEMM oracle at a fixed (m, k, n).
    pub fn gemm(&self, m: usize, k: usize, n: usize) -> PathBuf {
        self.dir.join(format!("gemm_{m}x{k}x{n}.hlo.txt"))
    }

    /// The quantized transformer block (Pallas kernel inside).
    pub fn transformer_block(&self) -> PathBuf {
        self.dir.join("transformer_block.hlo.txt")
    }

    /// The tiny greedy-decode step used by the serving example.
    pub fn decode_step(&self) -> PathBuf {
        self.dir.join("decode_step.hlo.txt")
    }

    /// True when `make artifacts` has produced the set.
    pub fn present(&self) -> bool {
        self.dir.join(".stamp").exists() || self.transformer_block().exists()
    }

    pub fn require(&self) -> crate::Result<()> {
        if self.present() {
            Ok(())
        } else {
            anyhow::bail!(
                "artifacts not found in {} — run `make artifacts` first",
                self.dir.display()
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        let a = ArtifactSet { dir: PathBuf::from("/x") };
        assert_eq!(a.gemm(8, 16, 4), PathBuf::from("/x/gemm_8x16x4.hlo.txt"));
        assert_eq!(a.transformer_block(), PathBuf::from("/x/transformer_block.hlo.txt"));
    }

    #[test]
    fn env_override() {
        // artifacts_dir reads the env var at call time.
        std::env::set_var("RACAM_ARTIFACTS", "/tmp/zzz");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/zzz"));
        std::env::remove_var("RACAM_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }
}
