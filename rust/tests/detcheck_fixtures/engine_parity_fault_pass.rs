// Fixture: the fault EventKinds of docs/robustness.md emitted the
// compliant way — inside a shared fault_step helper that both round
// paths call, so every fault variant reaches both engines.
pub enum EventKind {
    Admit,
    ShardCrash,
    Brownout,
}

pub fn emit(_k: EventKind) {}

fn fault_step() {
    emit(EventKind::ShardCrash);
    emit(EventKind::Brownout);
}

pub fn round_calendar() {
    emit(EventKind::Admit);
    fault_step();
}

pub fn round_oracle() {
    emit(EventKind::Admit);
    fault_step();
}
