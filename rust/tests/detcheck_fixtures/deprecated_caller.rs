// Fixture: calling a deprecated constructor from another module.
pub fn make() {
    #[allow(deprecated)]
    let _w = crate::widgets::Widget::legacy(2);
}

pub fn make_fresh() {
    let _w = crate::widgets::Widget::fresh();
}
