// Fixture: the allowed forms — asserts, unreachable!, and test-only
// unwraps are all fine under panic-hygiene.
pub fn parity(v: u32) -> u32 {
    assert!(v < 1_000_000, "id out of range");
    match v % 2 {
        0 => 0,
        1 => 1,
        _ => unreachable!("v % 2 is always 0 or 1"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_are_fine_in_tests() {
        let v: u64 = "7".parse().unwrap();
        assert_eq!(v, 7);
    }
}
