// Fixture: iterating a HashMap in result-facing coordinator code.
use std::collections::HashMap;

pub fn tally(xs: &[(u64, u64)]) -> u64 {
    let mut counts: HashMap<u64, u64> = HashMap::new();
    for (k, v) in xs {
        *counts.entry(*k).or_insert(0) += v;
    }
    let mut total = 0;
    for (_k, v) in &counts {
        total += v;
    }
    total
}
