// Fixture: a waiver without a `-- reason` is malformed and never waives.
use std::time::Instant;

pub fn stamp() -> u64 {
    // detcheck: allow(wall-clock)
    let t0 = Instant::now();
    t0.elapsed().as_nanos() as u64
}
