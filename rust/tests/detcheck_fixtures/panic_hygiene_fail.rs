// Fixture: panicking calls in library code.
pub fn parse(s: &str) -> u64 {
    let v: u64 = s.parse().unwrap();
    if v == 0 {
        panic!("zero is not a valid id");
    }
    v
}
