// Fixture: a Recorder impl that reads the host clock.  The module is
// exempt from wall-clock (experiments*), so the finding below comes
// from recorder-purity alone.
use std::time::Instant;

pub trait Recorder {
    fn begin(&mut self);
}

pub struct WallRecorder {
    pub t0: Option<Instant>,
}

impl Recorder for WallRecorder {
    fn begin(&mut self) {
        self.t0 = Some(Instant::now());
    }
}
