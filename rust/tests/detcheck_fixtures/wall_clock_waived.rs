// Fixture: the same clock read, carrying a reasoned inline waiver.
use std::time::Instant;

pub fn stamp() -> u64 {
    // detcheck: allow(wall-clock) -- fixture: the single per-run wall timer
    let t0 = Instant::now();
    t0.elapsed().as_nanos() as u64
}
