// Fixture: ad-hoc thread outside the deterministic executor.
use std::thread;

pub fn fan_out() {
    let handle = thread::spawn(|| {});
    handle.join().ok();
}
