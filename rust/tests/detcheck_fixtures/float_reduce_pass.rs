// Fixture: the pinned-order version — an explicit sequential fold.
pub fn mean(xs: &[f64]) -> f64 {
    let total = xs.iter().copied().fold(0.0, |acc, x| acc + x);
    total / xs.len() as f64
}
