// Fixture: DecodeStretch's oracle-side emission site was removed, so
// the variant now reaches only the calendar engine.
pub enum EventKind {
    Admit,
    DecodeStretch,
}

pub fn emit(_k: EventKind) {}

pub fn round_calendar() {
    emit(EventKind::Admit);
    emit(EventKind::DecodeStretch);
}

pub fn round_oracle() {
    emit(EventKind::Admit);
}
