// Fixture: a preempt_horizon hook with an unordered float reduction.
// The module is outside the float-reduce scope, so the finding comes
// from recorder-purity alone.
pub struct Lag {
    pub samples: Vec<f64>,
}

impl Lag {
    pub fn preempt_horizon(&self) -> f64 {
        self.samples.iter().copied().sum::<f64>()
    }
}
