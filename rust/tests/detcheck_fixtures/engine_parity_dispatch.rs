// Fixture: the dispatch layer emitting a variant both engines share
// through a common downstream path.
use super::engine::{emit, EventKind};

pub fn dispatch_handoffs() {
    emit(EventKind::HandoffDispatch);
}
