// Fixture: a fault variant injected outside the shared fault_step
// helper — ShardCrash is emitted straight from the calendar round, so
// the oracle engine would never observe the crash.
pub enum EventKind {
    Admit,
    ShardCrash,
}

pub fn emit(_k: EventKind) {}

pub fn round_calendar() {
    emit(EventKind::Admit);
    emit(EventKind::ShardCrash);
}

pub fn round_oracle() {
    emit(EventKind::Admit);
}
