// Fixture: a pure Recorder — counters only, no clocks or reductions.
pub trait Recorder {
    fn begin(&mut self);
}

pub struct CountRecorder {
    pub events: u64,
}

impl Recorder for CountRecorder {
    fn begin(&mut self) {
        self.events += 1;
    }
}
