// Fixture: unordered float reduction in an SLO aggregation path.
pub fn mean(xs: &[f64]) -> f64 {
    let total = xs.iter().copied().sum::<f64>();
    total / xs.len() as f64
}
