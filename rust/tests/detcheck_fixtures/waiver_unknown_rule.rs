// Fixture: a waiver naming a rule that does not exist.
pub fn clean() -> u64 {
    // detcheck: allow(flux-capacitor) -- fixture: no such rule
    42
}
