// Fixture: a miniature dual engine where every variant reaches both
// round paths — directly or through a helper — except the documented
// calendar-only BucketEdge, and HandoffDispatch which is emitted by
// the dispatch layer (see engine_parity_dispatch.rs).
pub enum EventKind {
    Admit,
    DecodeStretch,
    BucketEdge,
    HandoffDispatch,
}

pub fn emit(_k: EventKind) {}

fn decode_round() {
    emit(EventKind::DecodeStretch);
    emit(EventKind::BucketEdge);
}

pub fn round_calendar() {
    emit(EventKind::Admit);
    decode_round();
}

pub fn round_oracle() {
    emit(EventKind::Admit);
    emit(EventKind::DecodeStretch);
}
