// Fixture: a deprecated associated constructor and its builder
// replacement.  Calls inside the defining module are exempt.
pub struct Widget;

impl Widget {
    #[deprecated(note = "construct through WidgetBuilder")]
    pub fn legacy(n: u32) -> Widget {
        let _ = n;
        Widget
    }

    pub fn fresh() -> Widget {
        Widget
    }
}

pub fn local_caller() -> Widget {
    #[allow(deprecated)]
    Widget::legacy(1)
}
