// Fixture: the deterministic version — a BTreeMap iterates in key order.
use std::collections::BTreeMap;

pub fn tally(xs: &[(u64, u64)]) -> u64 {
    let mut counts: BTreeMap<u64, u64> = BTreeMap::new();
    for (k, v) in xs {
        *counts.entry(*k).or_insert(0) += v;
    }
    let mut total = 0;
    for (_k, v) in &counts {
        total += v;
    }
    total
}
