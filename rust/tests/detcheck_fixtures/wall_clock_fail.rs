// Fixture: host-clock read in simulation-facing library code.
use std::time::Instant;

pub fn stamp() -> u64 {
    let t0 = Instant::now();
    t0.elapsed().as_nanos() as u64
}
