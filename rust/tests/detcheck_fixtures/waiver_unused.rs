// Fixture: a waiver with nothing to waive is itself a finding.
pub fn clean() -> u64 {
    // detcheck: allow(wall-clock) -- fixture: nothing here needs this
    42
}
