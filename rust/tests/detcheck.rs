//! detcheck's own gate: fixture files analyzed under virtual paths
//! (the path drives rule scoping, so a fixture can impersonate any
//! module), lexer unit tests, and a self-scan over the real tree.
//!
//! The fixture sources in `detcheck_fixtures/` are never compiled —
//! cargo only builds top-level files in `tests/` — so they are free to
//! contain the exact constructs the rules ban.

use racam::analysis::{analyze, lexer, Finding, Report, SourceFile};

fn run(files: &[(&str, &str)]) -> Report {
    let files: Vec<SourceFile> = files
        .iter()
        .map(|&(path, src)| SourceFile { path: path.to_string(), src: src.to_string() })
        .collect();
    analyze(&files)
}

fn unwaived(report: &Report) -> Vec<&Finding> {
    report.findings.iter().filter(|f| f.waived.is_none()).collect()
}

const WALL_CLOCK_FAIL: &str = include_str!("detcheck_fixtures/wall_clock_fail.rs");
const WALL_CLOCK_WAIVED: &str = include_str!("detcheck_fixtures/wall_clock_waived.rs");
const WAIVER_UNUSED: &str = include_str!("detcheck_fixtures/waiver_unused.rs");
const WAIVER_MALFORMED: &str = include_str!("detcheck_fixtures/waiver_malformed.rs");
const WAIVER_UNKNOWN: &str = include_str!("detcheck_fixtures/waiver_unknown_rule.rs");
const MAP_ITER_FAIL: &str = include_str!("detcheck_fixtures/map_iteration_fail.rs");
const MAP_ITER_PASS: &str = include_str!("detcheck_fixtures/map_iteration_pass.rs");
const THREAD_FAIL: &str = include_str!("detcheck_fixtures/thread_spawn_fail.rs");
const FLOAT_FAIL: &str = include_str!("detcheck_fixtures/float_reduce_fail.rs");
const FLOAT_PASS: &str = include_str!("detcheck_fixtures/float_reduce_pass.rs");
const PANIC_FAIL: &str = include_str!("detcheck_fixtures/panic_hygiene_fail.rs");
const PANIC_PASS: &str = include_str!("detcheck_fixtures/panic_hygiene_pass.rs");
const DEPRECATED_DEF: &str = include_str!("detcheck_fixtures/deprecated_def.rs");
const DEPRECATED_CALLER: &str = include_str!("detcheck_fixtures/deprecated_caller.rs");
const RECORDER_FAIL: &str = include_str!("detcheck_fixtures/recorder_purity_fail.rs");
const RECORDER_HORIZON_FAIL: &str = include_str!("detcheck_fixtures/recorder_horizon_fail.rs");
const RECORDER_PASS: &str = include_str!("detcheck_fixtures/recorder_purity_pass.rs");
const ENGINE_PASS: &str = include_str!("detcheck_fixtures/engine_parity_pass.rs");
const ENGINE_DISPATCH: &str = include_str!("detcheck_fixtures/engine_parity_dispatch.rs");
const ENGINE_FAIL: &str = include_str!("detcheck_fixtures/engine_parity_fail.rs");
const ENGINE_FAULT_PASS: &str = include_str!("detcheck_fixtures/engine_parity_fault_pass.rs");
const ENGINE_FAULT_FAIL: &str = include_str!("detcheck_fixtures/engine_parity_fault_fail.rs");

// ------------------------------------------------------------------
// wall-clock
// ------------------------------------------------------------------

#[test]
fn wall_clock_flagged_in_lib_code() {
    let report = run(&[("src/traffic/gen.rs", WALL_CLOCK_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "wall-clock");
    assert_eq!(f[0].line, 5);
}

#[test]
fn wall_clock_exempt_in_allowlisted_module_and_test_targets() {
    for path in ["src/report/bench.rs", "src/runtime/executor.rs", "tests/timing.rs"] {
        let report = run(&[(path, WALL_CLOCK_FAIL)]);
        assert_eq!(report.unwaived_count(), 0, "{path}:\n{}", report.render());
    }
}

#[test]
fn wall_clock_waiver_accepted_and_counted() {
    let report = run(&[("src/traffic/gen.rs", WALL_CLOCK_WAIVED)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
    assert_eq!(report.waived_count(), 1);
    let reason = report.findings[0].waived.as_deref().unwrap_or_default();
    assert!(reason.contains("single per-run wall timer"), "reason: {reason}");
}

// ------------------------------------------------------------------
// waiver hygiene
// ------------------------------------------------------------------

#[test]
fn unused_waiver_is_a_finding() {
    let report = run(&[("src/traffic/gen.rs", WAIVER_UNUSED)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "waiver");
    assert!(f[0].hint.contains("unused"), "hint: {}", f[0].hint);
}

#[test]
fn waiver_without_reason_never_waives() {
    let report = run(&[("src/traffic/gen.rs", WAIVER_MALFORMED)]);
    // Findings sort by line: the malformed waiver (its comment line)
    // precedes the unwaived clock read on the next line.
    let rules: Vec<&str> = unwaived(&report).iter().map(|f| f.rule).collect();
    assert_eq!(rules, ["waiver", "wall-clock"], "{}", report.render());
}

#[test]
fn waiver_naming_unknown_rule_is_a_finding() {
    let report = run(&[("src/traffic/gen.rs", WAIVER_UNKNOWN)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "waiver");
    assert!(f[0].hint.contains("unknown rule"), "hint: {}", f[0].hint);
}

// ------------------------------------------------------------------
// map-iteration
// ------------------------------------------------------------------

#[test]
fn hash_map_iteration_flagged_in_coordinator() {
    let report = run(&[("src/coordinator/agg.rs", MAP_ITER_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "map-iteration");
    assert_eq!(f[0].line, 10);
}

#[test]
fn btree_map_iteration_passes() {
    let report = run(&[("src/coordinator/agg.rs", MAP_ITER_PASS)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

#[test]
fn hash_map_iteration_out_of_scope_passes() {
    let report = run(&[("src/pim/agg.rs", MAP_ITER_FAIL)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

// ------------------------------------------------------------------
// thread-spawn
// ------------------------------------------------------------------

#[test]
fn thread_spawn_flagged_outside_executor() {
    let report = run(&[("src/traffic/par.rs", THREAD_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "thread-spawn");
}

#[test]
fn thread_spawn_exempt_in_executor() {
    let report = run(&[("src/runtime/executor.rs", THREAD_FAIL)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

// ------------------------------------------------------------------
// float-reduce
// ------------------------------------------------------------------

#[test]
fn unordered_float_sum_flagged_in_scope() {
    let report = run(&[("src/coordinator/stats.rs", FLOAT_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "float-reduce");
}

#[test]
fn sequential_fold_passes() {
    let report = run(&[("src/coordinator/stats.rs", FLOAT_PASS)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

#[test]
fn float_sum_out_of_scope_passes() {
    let report = run(&[("src/pim/stats.rs", FLOAT_FAIL)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

// ------------------------------------------------------------------
// panic-hygiene
// ------------------------------------------------------------------

#[test]
fn unwrap_and_panic_flagged_in_lib_code() {
    let report = run(&[("src/traffic/parse.rs", PANIC_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 2, "{}", report.render());
    assert!(f.iter().all(|f| f.rule == "panic-hygiene"));
}

#[test]
fn asserts_unreachable_and_test_unwraps_pass() {
    let report = run(&[("src/traffic/parse.rs", PANIC_PASS)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

#[test]
fn panics_exempt_in_allowlisted_modules_and_bins() {
    for path in ["src/runtime/executor.rs", "src/experiments/scale.rs", "src/bin/tool.rs"] {
        let report = run(&[(path, PANIC_FAIL)]);
        assert_eq!(report.unwaived_count(), 0, "{path}:\n{}", report.render());
    }
}

// ------------------------------------------------------------------
// deprecated-internal
// ------------------------------------------------------------------

#[test]
fn deprecated_constructor_flagged_outside_defining_module() {
    let report = run(&[
        ("src/widgets.rs", DEPRECATED_DEF),
        ("src/report/make.rs", DEPRECATED_CALLER),
    ]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "deprecated-internal");
    assert_eq!(f[0].file, "src/report/make.rs");
}

#[test]
fn deprecated_constructor_allowed_in_defining_module() {
    let report = run(&[("src/widgets.rs", DEPRECATED_DEF)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

// ------------------------------------------------------------------
// recorder-purity
// ------------------------------------------------------------------

#[test]
fn recorder_impl_reading_clock_flagged() {
    // experiments* is exempt from wall-clock, so the finding below can
    // only come from recorder-purity.
    let report = run(&[("src/experiments/rec.rs", RECORDER_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "recorder-purity");
}

#[test]
fn preempt_horizon_float_reduce_flagged() {
    // mapping is outside the float-reduce scope, so the finding below
    // can only come from recorder-purity.
    let report = run(&[("src/mapping/lag.rs", RECORDER_HORIZON_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "recorder-purity");
}

#[test]
fn pure_recorder_passes() {
    let report = run(&[("src/telemetry/counters.rs", RECORDER_PASS)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

// ------------------------------------------------------------------
// engine-parity
// ------------------------------------------------------------------

#[test]
fn dual_engine_with_dispatch_layer_passes() {
    let report = run(&[
        ("src/coordinator/engine.rs", ENGINE_PASS),
        ("src/coordinator/wire.rs", ENGINE_DISPATCH),
    ]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

#[test]
fn removed_oracle_emission_site_fails_parity() {
    let report = run(&[("src/coordinator/engine.rs", ENGINE_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "engine-parity");
    assert!(
        f[0].hint.contains("only the calendar engine"),
        "hint: {}",
        f[0].hint
    );
}

#[test]
fn variant_with_no_emission_site_fails_parity() {
    // Without the dispatch-layer file, HandoffDispatch is emitted
    // nowhere in coordinator code.
    let report = run(&[("src/coordinator/engine.rs", ENGINE_PASS)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "engine-parity");
    assert!(f[0].hint.contains("no emission site"), "hint: {}", f[0].hint);
}

#[test]
fn fault_kinds_emitted_via_shared_fault_step_pass_parity() {
    // The docs/robustness.md contract: fault EventKinds are injected by
    // a fault_step helper both round paths call, so the rule sees them
    // reach both engines transitively.
    let report = run(&[("src/coordinator/engine.rs", ENGINE_FAULT_PASS)]);
    assert_eq!(report.unwaived_count(), 0, "{}", report.render());
}

#[test]
fn fault_kind_injected_outside_fault_step_fails_parity() {
    let report = run(&[("src/coordinator/engine.rs", ENGINE_FAULT_FAIL)]);
    let f = unwaived(&report);
    assert_eq!(f.len(), 1, "{}", report.render());
    assert_eq!(f[0].rule, "engine-parity");
    assert!(f[0].snippet.contains("ShardCrash"), "snippet: {}", f[0].snippet);
    assert!(
        f[0].hint.contains("only the calendar engine"),
        "hint: {}",
        f[0].hint
    );
}

// ------------------------------------------------------------------
// lexer
// ------------------------------------------------------------------

#[test]
fn raw_strings_are_scrubbed() {
    let lx = lexer::lex(r###"pub fn f() -> &'static str { r#"Instant::now()"# }"###);
    assert!(lx.toks.iter().all(|t| t.text != "Instant"));
    assert_eq!(lx.fns.len(), 1);
}

#[test]
fn nested_block_comments_are_scrubbed() {
    let lx = lexer::lex("/* outer /* inner */ still comment */ fn f() {}\n");
    assert!(lx.toks.iter().all(|t| t.text != "outer" && t.text != "still"));
    assert_eq!(lx.fns.len(), 1);
    assert_eq!(lx.fns[0].name, "f");
}

#[test]
fn cfg_test_regions_are_masked_but_not_cfg_not_test() {
    let src = "\
fn live() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    fn helper() {}\n\
}\n\
#[cfg(not(test))]\n\
fn also_live() {}\n";
    let lx = lexer::lex(src);
    let masked: Vec<&str> = lx
        .toks
        .iter()
        .zip(&lx.test_mask)
        .filter(|(_, &m)| m)
        .map(|(t, _)| t.text.as_str())
        .collect();
    assert!(masked.contains(&"helper"), "masked: {masked:?}");
    assert!(!masked.contains(&"live"));
    assert!(!masked.contains(&"also_live"));
}

#[test]
fn cfg_test_use_item_consumes_the_flag_without_a_region() {
    let src = "#[cfg(test)]\nuse std::time::Instant;\nfn live() {}\n";
    let lx = lexer::lex(src);
    assert!(lx.test_mask.iter().all(|&m| !m));
    assert_eq!(lx.fns.len(), 1);
}

#[test]
fn char_literals_and_lifetimes_are_distinguished() {
    let lx = lexer::lex("fn f<'a>(s: &'a str) -> char { let c = 'x'; let n = '\\n'; c }\n");
    // Literal contents are blanked; lifetime names survive as tokens.
    assert!(lx.toks.iter().all(|t| t.text != "x"));
    assert!(lx.toks.iter().any(|t| t.text == "a"));
    assert_eq!(lx.fns.len(), 1);
}

#[test]
fn waiver_must_lead_the_comment() {
    // A doc-comment *mention* of the syntax is not a waiver.
    let lx = lexer::lex("/// Use a `detcheck: allow(wall-clock) -- why` comment.\nfn f() {}\n");
    assert!(lx.waivers.is_empty());
    // A leading directive is, and covers the next token-bearing line.
    let lx = lexer::lex("// detcheck: allow(wall-clock) -- timer\nlet t = 1;\n");
    assert_eq!(lx.waivers.len(), 1);
    assert_eq!(lx.waivers[0].rule, "wall-clock");
    assert_eq!(lx.waivers[0].covers, 2);
    assert_eq!(lx.waivers[0].reason.as_deref(), Some("timer"));
    // A trailing same-line comment covers its own line.
    let lx = lexer::lex("let t = 1; // detcheck: allow(wall-clock) -- timer\n");
    assert_eq!(lx.waivers.len(), 1);
    assert_eq!(lx.waivers[0].covers, 1);
}

#[test]
fn impl_trait_in_argument_position_is_not_an_impl_block() {
    let lx = lexer::lex(
        "fn agg(xs: impl Iterator<Item = u64>) -> u64 { xs.sum() }\n\
         fn mk() -> impl Iterator<Item = u64> { 0..4 }\n\
         impl Widget { fn go(&self) {} }\n",
    );
    assert_eq!(lx.impls.len(), 1, "impl headers: {:?}", lx.impls);
    assert_eq!(lx.impls[0].header, ["Widget"]);
}

// ------------------------------------------------------------------
// machine-readable output + self-scan
// ------------------------------------------------------------------

#[test]
fn json_report_counts_match() {
    let report = run(&[
        ("src/traffic/gen.rs", WALL_CLOCK_FAIL),
        ("src/coordinator/stats.rs", FLOAT_PASS),
    ]);
    let v = report.to_json();
    assert_eq!(v.get("files").unwrap().as_u32().unwrap(), 2);
    assert_eq!(v.get("unwaived").unwrap().as_u32().unwrap(), 1);
    assert_eq!(v.get("waived").unwrap().as_u32().unwrap(), 0);
    // The report round-trips through the strict JSON parser.
    let parsed = racam::config::json::parse(&v.pretty()).unwrap();
    assert_eq!(parsed.get("unwaived").unwrap().as_u32().unwrap(), 1);
}

#[test]
fn the_real_tree_is_clean() {
    // The dogfood gate: the shipped source passes its own analysis,
    // and the waiver budget stays small enough to audit by hand.
    let report = racam::analysis::run_cli(&[]).unwrap();
    assert_eq!(report.unwaived_count(), 0, "\n{}", report.render());
    assert!(
        report.waived_count() <= 15,
        "waiver budget exceeded ({} > 15):\n{}",
        report.waived_count(),
        report.render()
    );
}
