//! Property-based tests over randomized inputs (a lightweight in-tree
//! harness stands in for `proptest`, which is unavailable offline: seeded
//! LCG generators, N cases per property, failing seed printed on panic).
//!
//! Coverage: coordinator invariants (batching, ordering, state), mapping
//! framework invariants, the functional bit-serial executor against the
//! scalar reference, the traffic generator (seed determinism, shard-count
//! invariance), ISA encode/decode, config JSON round-trips, and host-
//! executor determinism (randomized cluster spec × scheduler × traffic
//! seed, replayed across worker-pool sizes — the merged report must be
//! bit-identical on 1 thread, max threads, and oversubscribed pools).

use racam::config::{
    racam_paper, racam_tiny, ClusterSpec, HwConfig, LlmSpec, MatmulShape, Precision,
};
use racam::coordinator::{ClusterBuilder, FcfsBatcher, Request, Server, SyntheticEngine};
use racam::dram::{decode, encode, DramCommand};
use racam::mapping::{evaluate, enumerate_mappings, HwModel, MappingEngine, MappingService};
use racam::pim::{gemm_reference, BlockExecutor};
use racam::workloads::RacamSystem;

/// Minimal deterministic RNG (splitmix-ish over an LCG).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493))
    }
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 11
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
    fn signed(&mut self, bound: i64) -> i64 {
        (self.next() % (2 * bound as u64)) as i64 - bound
    }
}

/// A 2-layer model small enough that randomized serving cases stay fast.
fn tiny_llm() -> LlmSpec {
    LlmSpec {
        name: "tiny".into(),
        layers: 2,
        hidden: 256,
        heads: 4,
        kv_heads: 4,
        ffn: 512,
        gated_ffn: false,
        vocab: 512,
        prec: Precision::Int8,
    }
}

/// Run `cases` seeded property checks; the failing seed is in the panic.
fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Functional executor vs. scalar reference
// ---------------------------------------------------------------------------

#[test]
fn prop_bit_serial_gemm_equals_reference() {
    check("gemm==ref", 24, |rng| {
        let m = rng.range(1, 6) as usize;
        let k = rng.range(1, 300) as usize;
        let n = rng.range(1, 5) as usize;
        let prec = *[Precision::Int2, Precision::Int4, Precision::Int8]
            .iter()
            .nth(rng.range(0, 2) as usize)
            .unwrap();
        let bound = 1i64 << (prec.bits() - 1);
        let x: Vec<i64> = (0..m * k).map(|_| rng.signed(bound)).collect();
        let w: Vec<i64> = (0..k * n).map(|_| rng.signed(bound)).collect();
        let mut ex = BlockExecutor::new(&racam_tiny());
        let (got, stats) = ex.gemm(&x, &w, m, k, n, prec);
        assert_eq!(got, gemm_reference(&x, &w, m, k, n));
        assert_eq!(stats.macs, (m * k * n) as u64);
        // O(n) row traffic per pass.
        assert_eq!(stats.row_accesses, stats.passes * 4 * prec.bits() as u64);
    });
}

// ---------------------------------------------------------------------------
// Mapping framework invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_mapping_evaluations_are_sane() {
    let hw = HwModel::new(&racam_paper());
    check("mapping sanity", 12, |rng| {
        let shape = MatmulShape::new(
            rng.range(1, 4096),
            rng.range(1, 16384),
            rng.range(1, 16384),
            Precision::Int8,
        );
        let mappings = enumerate_mappings(&shape);
        assert_eq!(mappings.len(), if shape.m == 1 { 192 } else { 1458 });
        let mut best = f64::INFINITY;
        for mapping in mappings.iter().take(200) {
            let e = evaluate(&shape, mapping, &hw).expect("evaluates");
            let t = e.total_ns();
            assert!(t.is_finite() && t > 0.0, "{mapping}: {t}");
            assert!((0.0..=1.0).contains(&e.pe_util), "{mapping}: util {}", e.pe_util);
            for (u, a) in e.usage.used.iter().zip(e.usage.avail) {
                assert!(*u >= 1 && *u <= a);
            }
            // Tiles cover the problem.
            assert!(e.tile.0 * e.usage.used.iter().product::<u64>() >= 1);
            best = best.min(t);
        }
        assert!(best < f64::INFINITY);
    });
}

#[test]
fn prop_search_best_is_global_minimum() {
    let engine = MappingEngine::new(HwModel::new(&racam_paper()));
    check("search minimal", 6, |rng| {
        let shape = MatmulShape::new(
            rng.range(1, 512),
            rng.range(1, 8192),
            rng.range(1, 8192),
            Precision::Int8,
        );
        let r = engine.search(&shape).expect("non-degenerate shapes evaluate");
        for e in engine.evaluate_all(&shape) {
            assert!(r.best.total_ns() <= e.total_ns() + 1e-6);
        }
    });
}

#[test]
fn prop_parallel_search_matches_serial_reference() {
    // The exhaustive parallel search must return the exact serial winner —
    // same mapping, bit-identical latency, same candidate/worst
    // accounting — and the pruned paths (parallel and serial) the exact
    // same winner with the full space accounted for as evaluated+pruned.
    let service = MappingService::for_config(&racam_paper());
    check("parallel==serial", 6, |rng| {
        let shape = MatmulShape::new(
            rng.range(1, 64),
            rng.range(1, 4096),
            rng.range(1, 4096),
            Precision::Int8,
        );
        let ser = service.search_serial(&shape).expect("evaluates");
        let par = service.search_exhaustive(&shape).expect("evaluates");
        assert_eq!(par.best.mapping, ser.best.mapping);
        assert_eq!(par.best.total_ns().to_bits(), ser.best.total_ns().to_bits());
        assert_eq!(par.candidates, ser.candidates);
        assert_eq!(par.worst_ns.to_bits(), ser.worst_ns.to_bits());
        for pruned in
            [service.search(&shape).expect("evaluates"), service.search_serial_pruned(&shape).expect("evaluates")]
        {
            assert_eq!(pruned.best.mapping, ser.best.mapping);
            assert_eq!(pruned.best.total_ns().to_bits(), ser.best.total_ns().to_bits());
            assert_eq!(pruned.examined(), ser.candidates);
        }
    });
}

#[test]
fn prop_search_paths_agree_across_feature_ablations() {
    // The bit-identity contract must hold on *every* hardware model, not
    // just the paper preset: for random shapes × random feature ablations
    // (locality buffer, popcount reduction, broadcast unit), the
    // best-first search, the serial pruned walk, and the parallel
    // enumeration-order pruned scan must all return the serial exhaustive
    // winner bit-for-bit, with the full space accounted for as
    // evaluated + pruned.
    check("search ablations", 8, |rng| {
        let mut hw = racam_paper();
        hw.features.locality_buffer = rng.range(0, 1) == 1;
        hw.features.popcount_reduction = rng.range(0, 1) == 1;
        hw.features.broadcast_unit = rng.range(0, 1) == 1;
        let service = MappingService::for_config(&hw);
        let shape = MatmulShape::new(
            rng.range(1, 64),
            rng.range(1, 4096),
            rng.range(1, 4096),
            Precision::Int8,
        );
        let ser = service.search_serial(&shape).expect("evaluates");
        for r in [
            service.search_best_first(&shape).expect("evaluates"),
            service.search_serial_pruned(&shape).expect("evaluates"),
            service.search_enumeration_pruned(&shape).expect("evaluates"),
        ] {
            assert_eq!(r.best.mapping, ser.best.mapping);
            assert_eq!(r.best.total_ns().to_bits(), ser.best.total_ns().to_bits());
            assert_eq!(r.examined(), ser.candidates);
        }
    });
}

#[test]
fn prop_store_merge_is_commutative_and_idempotent() {
    // Concurrent processes fold their mapping tables through
    // `store::merge` in whatever order their drops race — the result must
    // not depend on that order, and re-merging anything already folded in
    // must be a byte-level no-op (canonical sort + deterministic
    // best-entry-per-key total order).
    use racam::mapping::store;
    check("store merge", 4, |rng| {
        let searched = |rng: &mut Rng| {
            let s = MappingService::for_config(&racam_paper());
            for _ in 0..rng.range(1, 4) {
                let shape = MatmulShape::new(
                    rng.range(1, 8),
                    rng.range(1, 2048),
                    rng.range(1, 2048),
                    Precision::Int8,
                );
                s.search_cached(&shape);
            }
            s
        };
        let a = store::export(&searched(rng));
        let b = store::export(&searched(rng));
        let ab = store::merge(&a, &b).unwrap();
        let ba = store::merge(&b, &a).unwrap();
        assert_eq!(ab.pretty(), ba.pretty(), "merge must commute to the byte");
        let again = store::merge(&ab, &b).unwrap();
        assert_eq!(again.pretty(), ab.pretty(), "re-merging a constituent must be a no-op");
        let twice = store::merge(&ab, &ab).unwrap();
        assert_eq!(twice.pretty(), ab.pretty(), "self-merge must be idempotent");
    });
}

#[test]
fn prop_merged_store_warm_starts_with_zero_additional_misses() {
    // Two services each search half the shapes and persist into the same
    // warm store on drop; a fresh service attached to the merged table
    // must answer every shape from the loaded entries — zero additional
    // searches.
    check("merged warm start", 3, |rng| {
        let dir = std::env::temp_dir().join("racam_proptest_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_{}_{}.json", std::process::id(), rng.next()));
        std::fs::remove_file(&path).ok();
        let mut shapes: Vec<MatmulShape> = Vec::new();
        let target = rng.range(2, 5) as usize;
        while shapes.len() < target {
            let s = MatmulShape::new(
                rng.range(1, 16),
                rng.range(1, 2048),
                rng.range(1, 2048),
                Precision::Int8,
            );
            if !shapes.contains(&s) {
                shapes.push(s);
            }
        }
        let mid = shapes.len() / 2;
        for half in [&shapes[..mid], &shapes[mid..]] {
            let s = MappingService::for_config(&racam_paper());
            s.set_warm_path(&path).unwrap();
            for shape in half {
                s.search_cached(shape);
            }
            drop(s); // last clone: merges the cache into the store
        }
        let warm = MappingService::for_config(&racam_paper());
        let loaded = warm.set_warm_path(&path).unwrap();
        assert_eq!(loaded, shapes.len(), "the merged table must hold both halves");
        for shape in &shapes {
            warm.search_cached(shape);
        }
        assert_eq!(warm.misses(), 0, "a merged table must answer every shape");
        assert_eq!(warm.hits(), shapes.len() as u64);
        drop(warm);
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_more_compute_never_faster_kernels() {
    // Monotonicity: growing any single GEMM dimension must not reduce the
    // best-mapping latency.
    let engine = MappingEngine::new(HwModel::new(&racam_paper()));
    check("monotone dims", 8, |rng| {
        let m = rng.range(1, 256);
        let k = rng.range(64, 8192);
        let n = rng.range(64, 8192);
        let best_ns =
            |shape: MatmulShape| engine.search(&shape).expect("evaluates").best.total_ns();
        let base = best_ns(MatmulShape::new(m, k, n, Precision::Int8));
        let grow_k = best_ns(MatmulShape::new(m, k * 2, n, Precision::Int8));
        let grow_n = best_ns(MatmulShape::new(m, k, n * 2, Precision::Int8));
        // Allow 2% slack for ceil effects in tiling.
        assert!(grow_k >= base * 0.98, "K: {base} -> {grow_k}");
        assert!(grow_n >= base * 0.98, "N: {base} -> {grow_n}");
    });
}

// ---------------------------------------------------------------------------
// Coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_exceeds_capacity_and_preserves_fcfs() {
    check("batcher", 40, |rng| {
        let max_batch = rng.range(1, 8) as usize;
        let mut b = FcfsBatcher::new(max_batch);
        let total = rng.range(1, 30);
        for id in 0..total {
            b.submit(Request::new(id, vec![1], 1));
        }
        let mut seen = Vec::new();
        let mut running = rng.range(0, max_batch as u64) as usize;
        while b.pending() > 0 {
            let admitted = b.admit(running);
            assert!(admitted.len() + running <= max_batch, "over-admitted");
            seen.extend(admitted.iter().map(|r| r.id));
            running = 0; // all retire before next round
        }
        let expect: Vec<u64> = (0..total).collect();
        assert_eq!(seen, expect, "FCFS order violated");
    });
}

#[test]
fn prop_server_conserves_requests_and_tokens() {
    check("server conservation", 6, |rng| {
        let engine = SyntheticEngine::new(32, 64);
        let spec = racam::config::gpt3_6_7b();
        let mut server =
            Server::new(engine, RacamSystem::new(&racam_paper()), spec, rng.range(1, 4) as usize);
        let n_req = rng.range(1, 6);
        let mut expected_tokens = 0;
        for id in 0..n_req {
            let toks = rng.range(1, 8) as usize;
            expected_tokens += toks;
            let prompt: Vec<u32> = (0..rng.range(1, 6)).map(|_| rng.range(0, 63) as u32).collect();
            server.submit(Request::new(id, prompt, toks));
        }
        let report = server.run_to_completion().unwrap();
        assert_eq!(report.results.len(), n_req as usize);
        assert_eq!(report.total_tokens, expected_tokens);
        // Results sorted by id, each fully generated.
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        // Simulated hardware time moves forward.
        assert!(report.sim_tokens_per_s > 0.0);
    });
}

#[test]
fn prop_generation_independent_of_batching() {
    // The batch schedule must not change any request's greedy generation.
    check("batch independence", 4, |rng| {
        let prompts: Vec<Vec<u32>> =
            (0..3).map(|i| vec![i as u32 + 1, rng.range(0, 63) as u32]).collect();
        let gen = |batch: usize| -> Vec<Vec<u32>> {
            let mut server = Server::new(
                SyntheticEngine::new(32, 64),
                RacamSystem::new(&racam_paper()),
                racam::config::gpt3_6_7b(),
                batch,
            );
            for (id, p) in prompts.iter().enumerate() {
                server.submit(Request::new(id as u64, p.clone(), 5));
            }
            server.run_to_completion().unwrap().results.into_iter().map(|r| r.tokens).collect()
        };
        assert_eq!(gen(1), gen(3));
    });
}

#[test]
fn prop_sharding_conserves_requests_and_generation() {
    // Splitting the same request set across worker shards must not change
    // any request's tokens, and every request must complete exactly once.
    check("shard independence", 3, |rng| {
        let reqs: Vec<Request> = (0..rng.range(2, 6))
            .map(|id| {
                Request::new(
                    id,
                    vec![id as u32 + 1, rng.range(0, 63) as u32],
                    rng.range(1, 6) as usize,
                )
            })
            .collect();
        let run = |shards: usize| -> Vec<(u64, Vec<u32>)> {
            let mut coord = ClusterBuilder::new(
                ClusterSpec::unified(shards, 2),
                &racam_paper(),
                racam::config::gpt3_6_7b(),
            )
            .unwrap()
            .build(|_| SyntheticEngine::new(32, 64));
            for r in &reqs {
                coord.submit(r.clone());
            }
            let report = coord.run_to_completion().unwrap();
            assert_eq!(report.results.len(), reqs.len());
            report.results.into_iter().map(|r| (r.id, r.tokens)).collect()
        };
        assert_eq!(run(1), run(3));
    });
}

// ---------------------------------------------------------------------------
// Traffic generator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_traffic_generator_is_deterministic_per_seed() {
    use racam::config::{ArrivalProcess, LengthDist, TrafficSpec};
    use racam::traffic::generate;

    check("traffic determinism", 16, |rng| {
        let spec = TrafficSpec {
            seed: rng.next(),
            requests: rng.range(1, 40),
            arrival: if rng.range(0, 1) == 0 {
                ArrivalProcess::Poisson { rate_per_s: rng.range(10, 2000) as f64 }
            } else {
                ArrivalProcess::Bursty {
                    rate_per_s: rng.range(10, 2000) as f64,
                    burst: rng.range(1, 8) as u32,
                }
            },
            prompt: LengthDist::Uniform { lo: 1, hi: rng.range(2, 256) },
            output: LengthDist::LogNormal {
                median: rng.range(1, 64),
                sigma: 0.5,
                cap: 256,
            },
            deadline_ns: Some(rng.range(1, 1_000_000_000)),
        };
        // Same seed ⇒ bit-identical stream across repeated materialization.
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a, b, "same spec must regenerate the same stream");
        // Different seed ⇒ a different stream (arrivals and contents).
        let mut other = spec.clone();
        other.seed = spec.seed.wrapping_add(1);
        assert_ne!(generate(&other), a, "seed must matter");
        // Arrival order and deadlines are coherent.
        for w in a.windows(2) {
            assert!(w[0].arrival_ns <= w[1].arrival_ns);
        }
        for r in &a {
            assert!(!r.prompt.is_empty());
            // Per-request budgets spread over [0.5x, 1.5x] the spec mean.
            let budget = r.deadline_ns.expect("spec sets a deadline") - r.arrival_ns;
            let mean = spec.deadline_ns.unwrap();
            assert!(budget >= (mean / 2).max(1) && budget <= mean / 2 * 3 + 1, "budget {budget}");
        }
    });
}

#[test]
fn prop_traffic_stream_is_shard_count_invariant() {
    // The generated stream is fixed before dispatch, so serving it on 1 or
    // 3 shards must complete the same request set with the same tokens.
    use racam::config::{ArrivalProcess, LengthDist, TrafficSpec};
    use racam::traffic::generate;

    check("traffic shard invariance", 2, |rng| {
        let spec = TrafficSpec {
            seed: rng.next(),
            requests: rng.range(2, 6),
            arrival: ArrivalProcess::Poisson { rate_per_s: 500.0 },
            prompt: LengthDist::Uniform { lo: 1, hi: 8 },
            output: LengthDist::Uniform { lo: 1, hi: 4 },
            deadline_ns: None,
        };
        let stream = generate(&spec);
        let run = |shards: usize| -> Vec<(u64, Vec<u32>)> {
            let mut coord = ClusterBuilder::new(
                ClusterSpec::unified(shards, 2),
                &racam_paper(),
                racam::config::gpt3_6_7b(),
            )
            .unwrap()
            .build(|_| SyntheticEngine::new(32, 64));
            for r in &stream {
                coord.submit(r.clone());
            }
            let report = coord.run_to_completion().unwrap();
            assert_eq!(report.results.len(), stream.len());
            report.results.into_iter().map(|r| (r.id, r.tokens)).collect()
        };
        assert_eq!(run(1), run(3));
    });
}

// ---------------------------------------------------------------------------
// ISA + config round-trips
// ---------------------------------------------------------------------------

#[test]
fn prop_command_encode_decode_roundtrip() {
    check("isa roundtrip", 200, |rng| {
        let dst = rng.range(0, 255) as u8;
        let s1 = rng.range(0, 255) as u8;
        let s2 = rng.range(0, 255) as u8;
        let prec = rng.range(0, 15) as u8;
        let cmd = match rng.range(0, 4) {
            0 => DramCommand::PimAdd { r_dst: dst, r_src1: s1, r_src2: s2, prec },
            1 => DramCommand::PimMul { r_dst: dst, r_src1: s1, r_src2: s2, prec },
            2 => DramCommand::PimMulRed { r_dst: dst, r_src1: s1, r_src2: s2, prec },
            3 => DramCommand::PimAddParallel { r_dst: dst, r_src1: s1, r_src2: s2 },
            _ => DramCommand::BroadcastEnable {
                bank_bc: rng.range(0, 1) == 1,
                col_bc: rng.range(0, 1) == 1,
            },
        };
        assert_eq!(decode(encode(&cmd).unwrap()), Some(cmd));
    });
}

// ---------------------------------------------------------------------------
// Host-executor determinism (the work-stealing worker pool)
// ---------------------------------------------------------------------------

mod host_executor {
    use super::{check, racam_paper, ClusterBuilder, ClusterSpec, RacamSystem, Rng, Server,
                SyntheticEngine};
    use racam::config::{
        ArrivalProcess, EngineKind, HostExecutor, LengthDist, SchedulerKind, ServingPolicy,
        TrafficSpec,
    };
    use racam::coordinator::{BatchPoll, ServerReport, ShardRun};
    use racam::runtime::executor;
    use racam::traffic::generate;

    fn random_scheduler(rng: &mut Rng) -> SchedulerKind {
        [SchedulerKind::Fcfs, SchedulerKind::Bucketed, SchedulerKind::Edf]
            [rng.range(0, 2) as usize]
    }

    fn random_policy(rng: &mut Rng, allow_preempt: bool) -> ServingPolicy {
        let base = match rng.range(0, 2) {
            0 => ServingPolicy::whole_prefill(),
            1 => ServingPolicy::chunked(64 << rng.range(0, 2)),
            _ if allow_preempt => ServingPolicy::chunked(128).with_preemption(),
            _ => ServingPolicy::chunked(128),
        };
        // A quarter of the shapes run the per-iteration oracle: the pool
        // must be a no-op for both serving-loop implementations.
        if rng.range(0, 3) == 0 {
            base.with_engine(EngineKind::Oracle)
        } else {
            base
        }
    }

    /// A random serving shape: unified (1–4 shards, any scheduler/policy)
    /// or prefill/decode-disaggregated (preemption off, like the
    /// `exp disagg` preset).
    fn random_cluster(rng: &mut Rng) -> ClusterSpec {
        let max_batch = rng.range(1, 4) as usize;
        let disagg = rng.range(0, 2) == 0;
        let mut spec = if disagg {
            ClusterSpec::disaggregated(
                rng.range(1, 2) as usize,
                rng.range(1, 2) as usize,
                max_batch,
            )
        } else {
            ClusterSpec::unified(rng.range(1, 4) as usize, max_batch)
        };
        for g in &mut spec.groups {
            g.scheduler = random_scheduler(rng);
            g.policy = random_policy(rng, !disagg);
        }
        spec
    }

    fn random_stream(rng: &mut Rng) -> TrafficSpec {
        TrafficSpec {
            seed: rng.next(),
            requests: rng.range(20, 60),
            arrival: ArrivalProcess::Poisson { rate_per_s: rng.range(500, 4_000) as f64 },
            prompt: LengthDist::Uniform { lo: 8, hi: 8 + (64 << rng.range(0, 2)) },
            output: LengthDist::Uniform { lo: 4, hi: rng.range(8, 32) },
            deadline_ns: match rng.range(0, 2) {
                0 => None,
                _ => Some(rng.range(20_000_000, 200_000_000)),
            },
        }
    }

    /// Run `spec` over `stream` on the given worker pool; merged report.
    fn run_cluster(spec: &ClusterSpec, stream: &TrafficSpec, exec: HostExecutor) -> ServerReport {
        let mut coord = ClusterBuilder::new(spec.clone(), &racam_paper(), super::tiny_llm())
            .unwrap()
            .build(|_| SyntheticEngine::new(32, 64));
        coord.set_executor(exec);
        for req in generate(stream) {
            coord.submit(req);
        }
        coord.run_to_completion().unwrap()
    }

    fn assert_identical(label: &str, a: &ServerReport, b: &ServerReport) {
        if let Some(d) = a.sim_divergence(b) {
            panic!("{label}: reports diverged: {d}");
        }
    }

    /// The tentpole property: for any cluster shape × scheduler × stream,
    /// the merged report is a pure function of the inputs — the worker-
    /// pool size (1, 2, max, and an oversubscribed 2×max+1) never leaks
    /// into any simulated quantity.
    #[test]
    fn prop_report_invariant_across_thread_counts() {
        check("thread invariance", 6, |rng| {
            let spec = random_cluster(rng);
            let stream = random_stream(rng);
            let base = run_cluster(&spec, &stream, HostExecutor::with_threads(1));
            assert_eq!(base.results.len() as u64, stream.requests);
            let max = executor::available_parallelism();
            let mut pools = vec![2, max, 2 * max + 1];
            pools.sort_unstable();
            pools.dedup();
            for t in pools {
                let rep = run_cluster(&spec, &stream, HostExecutor::with_threads(t));
                assert_identical(
                    &format!("{} shard(s) on t{t}", spec.total_shards()),
                    &rep,
                    &base,
                );
            }
        });
    }

    /// Shards ≫ cores: a 24-shard cluster on small pools keeps every
    /// worker's deque loaded and forces continuous stealing — the regime
    /// where an unprotected result slot or a double-poll would corrupt a
    /// report.
    #[test]
    fn prop_many_more_shards_than_cores_stay_deterministic() {
        check("24 shards", 2, |rng| {
            let mut spec = ClusterSpec::unified(24, 2);
            spec.groups[0].scheduler = random_scheduler(rng);
            let mut stream = random_stream(rng);
            stream.requests = 96;
            let base = run_cluster(&spec, &stream, HostExecutor::with_threads(1));
            assert_eq!(base.shards.len(), 24);
            for t in [2, 3, executor::available_parallelism()] {
                let rep = run_cluster(&spec, &stream, HostExecutor::with_threads(t));
                assert_identical(&format!("24 shards on t{t}"), &rep, &base);
            }
        });
    }

    /// The stealing granularity is a pure host-side knob too: pathological
    /// batch sizes (1 round per poll — maximal task churn) and huge ones
    /// (the whole run in one poll) produce the same merged report.
    #[test]
    fn prop_batch_rounds_granularity_does_not_change_results() {
        check("batch rounds", 3, |rng| {
            let spec = random_cluster(rng);
            let stream = random_stream(rng);
            let base = run_cluster(&spec, &stream, HostExecutor::with_threads(1));
            for rounds in [1, 7, u64::MAX] {
                let exec = HostExecutor { threads: Some(2), batch_rounds: rounds };
                let rep = run_cluster(&spec, &stream, exec);
                assert_identical(&format!("batch_rounds {rounds}"), &rep, &base);
            }
        });
    }

    /// The resumable-round seam itself: driving one server through
    /// [`ShardRun`] in tiny poll batches must equal the same server's
    /// blocking `run_to_completion` bit-for-bit — the executor path is
    /// the same loop, only sliced.
    #[test]
    fn prop_batched_shard_run_equals_run_to_completion() {
        check("sliced run", 4, |rng| {
            let stream = random_stream(rng);
            let policy = random_policy(rng, false);
            let build = |stream: &TrafficSpec| {
                let mut s = Server::new(
                    SyntheticEngine::new(32, 64),
                    RacamSystem::new(&racam_paper()),
                    super::tiny_llm(),
                    3,
                )
                .with_policy(policy);
                for req in generate(stream) {
                    s.submit(req);
                }
                s
            };
            let blocking = build(&stream).run_to_completion().unwrap();
            let mut server = build(&stream);
            let mut run = ShardRun::new(&mut server);
            let batch = rng.range(1, 3);
            let mut polls = 0u32;
            let sliced = loop {
                match run.poll(batch).unwrap() {
                    BatchPoll::Finished => break run.finish(),
                    BatchPoll::Progressed => polls += 1,
                    BatchPoll::WouldBlock => panic!("blocked without an intake"),
                }
                assert!(polls < 10_000_000, "sliced run did not terminate");
            };
            assert_identical("sliced-vs-blocking", &sliced, &blocking);
        });
    }
}

// ---------------------------------------------------------------------------
// Deterministic chaos (randomized fault schedules, docs/robustness.md)
// ---------------------------------------------------------------------------

mod chaos {
    use super::{check, racam_paper, ClusterBuilder, ClusterSpec, Rng, SyntheticEngine};
    use racam::config::{
        ArrivalProcess, EngineKind, FaultEvent, FaultSpec, LengthDist, RecoveryPolicy,
        TrafficSpec,
    };
    use racam::coordinator::ServerReport;
    use racam::traffic::generate;

    /// A random schedule of crashes, brownouts, link outages, and link
    /// degradation over a cluster of `shards` shards (onsets within the
    /// first ~50 simulated ms, where these streams actually serve), with
    /// a random bounded-retry recovery policy.
    fn random_faults(rng: &mut Rng, shards: usize) -> FaultSpec {
        let mut events = Vec::new();
        let mut crashed: Vec<usize> = Vec::new();
        for _ in 0..rng.range(1, 3) {
            let at_ns = rng.range(0, 50_000_000) as f64;
            match rng.range(0, 3) {
                0 => {
                    let shard = rng.range(0, shards as u64 - 1) as usize;
                    if !crashed.contains(&shard) {
                        crashed.push(shard);
                        events.push(FaultEvent::ShardCrash { shard, at_ns });
                    }
                }
                1 => events.push(FaultEvent::Brownout {
                    shard: rng.range(0, shards as u64 - 1) as usize,
                    start_ns: at_ns,
                    end_ns: at_ns + rng.range(1_000_000, 40_000_000) as f64,
                    slowdown: 1.0 + rng.range(0, 20) as f64 / 10.0,
                }),
                2 => events.push(FaultEvent::LinkOutage {
                    start_ns: at_ns,
                    end_ns: at_ns + rng.range(100_000, 10_000_000) as f64,
                }),
                _ => events.push(FaultEvent::LinkDegrade {
                    start_ns: at_ns,
                    end_ns: at_ns + rng.range(1_000_000, 40_000_000) as f64,
                    factor: rng.range(1, 10) as f64 / 10.0,
                }),
            }
        }
        FaultSpec {
            seed: rng.next(),
            events,
            recovery: RecoveryPolicy {
                retry_budget: rng.range(0, 3) as u32,
                utilization_ceiling: rng.range(0, 2) as f64 / 2.0,
                ..RecoveryPolicy::default()
            },
        }
    }

    fn random_stream(rng: &mut Rng, deadlines: bool) -> TrafficSpec {
        TrafficSpec {
            seed: rng.next(),
            requests: rng.range(16, 40),
            arrival: ArrivalProcess::Poisson { rate_per_s: rng.range(500, 4_000) as f64 },
            prompt: LengthDist::Uniform { lo: 8, hi: 8 + (64 << rng.range(0, 2)) },
            output: LengthDist::Uniform { lo: 4, hi: rng.range(8, 24) },
            deadline_ns: if deadlines && rng.range(0, 1) == 1 {
                Some(rng.range(20_000_000, 200_000_000))
            } else {
                None
            },
        }
    }

    fn run_faulted(
        spec: &ClusterSpec,
        stream: &TrafficSpec,
        faults: &FaultSpec,
        engine: EngineKind,
    ) -> ServerReport {
        let mut spec = spec.clone();
        for g in &mut spec.groups {
            g.policy = g.policy.with_engine(engine);
        }
        let mut coord = ClusterBuilder::new(spec, &racam_paper(), super::tiny_llm())
            .unwrap()
            .build(|_| SyntheticEngine::new(32, 64));
        coord.set_faults(faults).unwrap();
        for req in generate(stream) {
            coord.submit(req);
        }
        coord.run_to_completion().unwrap()
    }

    /// Conservation under chaos: for any cluster shape × fault schedule ×
    /// stream, every submitted request appears in the merged report
    /// exactly once, in exactly one terminal state (delivered, shed, or
    /// failed) — and the whole report is engine-invariant, recovery
    /// accounting included.
    #[test]
    fn prop_faulted_runs_conserve_every_request() {
        check("chaos conservation", 6, |rng| {
            let spec = if rng.range(0, 1) == 0 {
                ClusterSpec::unified(rng.range(1, 4) as usize, rng.range(1, 4) as usize)
            } else {
                ClusterSpec::disaggregated(
                    rng.range(1, 2) as usize,
                    rng.range(1, 2) as usize,
                    rng.range(1, 4) as usize,
                )
            };
            let stream = random_stream(rng, true);
            let faults = random_faults(rng, spec.total_shards());
            let rep = run_faulted(&spec, &stream, &faults, EngineKind::Calendar);
            assert_eq!(
                rep.results.len() as u64,
                stream.requests,
                "every request must reach a terminal state exactly once"
            );
            for (i, r) in rep.results.iter().enumerate() {
                assert_eq!(r.id, i as u64, "merged results are id-sorted and duplicate-free");
                assert!(!(r.shed && r.failed), "req {}: shed and failed are exclusive", r.id);
                if r.failed {
                    assert!(r.tokens.is_empty(), "req {}: failed requests deliver nothing", r.id);
                }
            }
            let delivered =
                rep.results.iter().filter(|r| !r.shed && !r.failed).count();
            let shed = rep.results.iter().filter(|r| r.shed).count();
            let failed = rep.results.iter().filter(|r| r.failed).count();
            assert_eq!(delivered + shed + failed, rep.results.len());
            let oracle = run_faulted(&spec, &stream, &faults, EngineKind::Oracle);
            if let Some(d) = rep.sim_divergence(&oracle) {
                panic!("chaos engines diverged: {d}");
            }
        });
    }

    /// Link faults never duplicate work: under outage/degradation-only
    /// schedules (no crashes) on a disaggregated cluster, every request
    /// still crosses the KV link exactly once — retries re-send the same
    /// transfer, they do not re-hand-off — and the run is reproducible
    /// bit-for-bit.
    #[test]
    fn prop_link_faults_never_duplicate_handoffs() {
        check("no duplicate handoffs", 6, |rng| {
            let spec = ClusterSpec::disaggregated(
                rng.range(1, 2) as usize,
                rng.range(1, 2) as usize,
                rng.range(1, 4) as usize,
            );
            let mut faults = random_faults(rng, spec.total_shards());
            faults.events.retain(|e| {
                matches!(e, FaultEvent::LinkOutage { .. } | FaultEvent::LinkDegrade { .. })
            });
            faults.events.push(FaultEvent::LinkOutage {
                start_ns: 0.0,
                end_ns: rng.range(100_000, 5_000_000) as f64,
            });
            let stream = random_stream(rng, false);
            let rep = run_faulted(&spec, &stream, &faults, EngineKind::Calendar);
            let handoffs: usize = rep.shards.iter().map(|s| s.handoffs).sum();
            assert_eq!(
                handoffs as u64, stream.requests,
                "each request crosses the link exactly once"
            );
            assert!(rep.results.iter().all(|r| !r.shed && !r.failed));
            let again = run_faulted(&spec, &stream, &faults, EngineKind::Calendar);
            if let Some(d) = rep.sim_divergence(&again) {
                panic!("faulted rerun diverged: {d}");
            }
        });
    }

    /// KV-link cost is monotone in the outage schedule: adding one more
    /// outage window to an outage-only schedule can only delay transfers
    /// (queueing + backoff are non-negative, wire time is unchanged), so
    /// the cluster-total `kv_transfer_ns` never decreases.
    #[test]
    fn prop_kv_transfer_is_monotone_under_added_outages() {
        check("kv outage monotone", 6, |rng| {
            let spec = ClusterSpec::disaggregated(
                rng.range(1, 2) as usize,
                rng.range(1, 2) as usize,
                rng.range(1, 4) as usize,
            );
            let mut base = random_faults(rng, spec.total_shards());
            base.events.retain(|e| matches!(e, FaultEvent::LinkOutage { .. }));
            let stream = random_stream(rng, false);
            let kv_total = |faults: &FaultSpec| -> f64 {
                let rep = run_faulted(&spec, &stream, faults, EngineKind::Calendar);
                rep.shards.iter().map(|s| s.kv_transfer_ns).fold(0.0, f64::max)
                    + rep.shards.iter().map(|s| s.kv_transfer_ns).sum::<f64>()
            };
            // `base` may be outage-free: set_faults rejects nothing here
            // either way, and the comparison below still applies.
            let without = kv_total(&base);
            let start_ns = rng.range(0, 20_000_000) as f64;
            base.events.push(FaultEvent::LinkOutage {
                start_ns,
                end_ns: start_ns + rng.range(500_000, 10_000_000) as f64,
            });
            let with = kv_total(&base);
            assert!(
                with >= without,
                "adding an outage window reduced total kv transfer: {with} < {without}"
            );
        });
    }
}

#[test]
fn prop_config_json_roundtrip_with_mutations() {
    check("config json", 30, |rng| {
        let mut hw = racam_paper();
        hw.dram.channels = rng.range(1, 16) as u32;
        hw.dram.ranks = rng.range(1, 64) as u32;
        hw.periph.pes_per_bank = 1 << rng.range(5, 11);
        hw.periph.locality_buffer_cols = hw.periph.pes_per_bank;
        hw.timing.channel_efficiency = rng.range(50, 100) as f64 / 100.0;
        hw.features.broadcast_unit = rng.range(0, 1) == 1;
        let back = HwConfig::from_json(&hw.to_json()).unwrap();
        assert_eq!(hw, back);
    });
}

/// Properties of the telemetry registry ([`racam::telemetry`]): the
/// multi-threaded determinism story rests entirely on histogram and
/// metrics merges being *exactly* associative and commutative, so shard
/// results folded in shard order are bit-identical no matter which
/// worker produced them or how the fold is grouped.
mod telemetry_registry {
    use super::{check, Rng};
    use racam::telemetry::{quantize_ns, Histogram, Metrics};

    /// Random `(value, multiplicity)` samples spanning the full bucket
    /// range — shifting a raw 53-bit draw by a random amount lands
    /// values in every log2 bucket, exercising the bucket-edge math.
    fn samples(rng: &mut Rng) -> Vec<(u64, u64)> {
        (0..rng.range(0, 24)).map(|_| (rng.next() >> rng.range(0, 52), rng.range(1, 3))).collect()
    }

    fn hist_of(samples: &[(u64, u64)]) -> Histogram {
        let mut h = Histogram::new();
        for &(v, n) in samples {
            h.record_n(v, n);
        }
        h
    }

    /// Histogram merge commutes, associates, has the empty histogram as
    /// identity, and equals recording every sample into one histogram —
    /// integer counts/sum/min/max only, so equality is exact.
    #[test]
    fn prop_histogram_merge_is_associative_and_commutative() {
        check("hist merge", 60, |rng| {
            let (sa, sb, sc) = (samples(rng), samples(rng), samples(rng));
            let (a, b, c) = (hist_of(&sa), hist_of(&sb), hist_of(&sc));

            let mut ab = a;
            ab.merge(&b);
            let mut ba = b;
            ba.merge(&a);
            assert_eq!(ab, ba, "merge must commute");

            let mut ab_c = ab;
            ab_c.merge(&c);
            let mut bc = b;
            bc.merge(&c);
            let mut a_bc = a;
            a_bc.merge(&bc);
            assert_eq!(ab_c, a_bc, "merge must associate");

            let mut flat = sa.clone();
            flat.extend(&sb);
            flat.extend(&sc);
            assert_eq!(ab_c, hist_of(&flat), "merge must equal one-pass recording");

            let mut a_id = a;
            a_id.merge(&Histogram::new());
            assert_eq!(a_id, a, "empty histogram must be the merge identity");
        });
    }

    /// `quantize_ns` is total over anything the simulated clock can
    /// produce (NaN and negatives fold to 0) and preserves ordering, so
    /// bucketing simulated durations never panics or inverts.
    #[test]
    fn prop_quantize_ns_is_total_and_monotone() {
        check("quantize", 60, |rng| {
            let x = rng.range(0, 1_000_000_000) as f64 / 7.0;
            let y = x + rng.range(1, 1_000_000) as f64;
            assert!(quantize_ns(x) <= quantize_ns(y), "quantize must be monotone");
            assert_eq!(quantize_ns(-x - 1.0), 0);
            assert_eq!(quantize_ns(f64::NAN), 0);
            let mut h = Histogram::new();
            h.record_ns(x);
            assert_eq!(h.len(), 1);
            assert_eq!(h.min(), quantize_ns(x));
            assert_eq!(h.max(), quantize_ns(x));
        });
    }

    fn random_metrics(rng: &mut Rng) -> Metrics {
        let mut m = Metrics {
            requests: rng.range(0, 40),
            delivered: rng.range(0, 40),
            shed: rng.range(0, 5),
            preemptions: rng.range(0, 5),
            prefill_chunks: rng.range(0, 100),
            decode_iterations: rng.range(0, 1000),
            handoffs: rng.range(0, 40),
            total_tokens: rng.range(0, 10_000),
            ..Metrics::default()
        };
        for (v, n) in samples(rng) {
            m.ttft_ns.record_n(v, n);
        }
        for (v, n) in samples(rng) {
            m.tpot_ns.record_n(v, n);
        }
        for (v, n) in samples(rng) {
            m.queue_depth.record_n(v % 64, n);
        }
        for (v, n) in samples(rng) {
            m.batch_occupancy.record_n(v % 32, n);
        }
        m
    }

    /// Folding per-shard registries in shard order is deterministic:
    /// [`Metrics::merged`] (a left fold) equals a pairwise tree
    /// reduction over the same slice, and repeating the fold reproduces
    /// itself bit-for-bit.
    #[test]
    fn prop_metrics_merge_in_shard_order_is_deterministic() {
        check("metrics merge", 40, |rng| {
            let shards: Vec<Metrics> = (0..rng.range(1, 9)).map(|_| random_metrics(rng)).collect();

            let left_fold = Metrics::merged(&shards);
            assert_eq!(left_fold, Metrics::merged(&shards), "fold must be reproducible");

            let mut layer = shards.clone();
            while layer.len() > 1 {
                layer = layer
                    .chunks(2)
                    .map(|pair| {
                        let mut m = pair[0];
                        if let Some(right) = pair.get(1) {
                            m.merge(right);
                        }
                        m
                    })
                    .collect();
            }
            assert_eq!(layer[0], left_fold, "tree reduction must equal the left fold");

            let mut with_identity = Metrics::default();
            with_identity.merge(&left_fold);
            assert_eq!(with_identity, left_fold, "default metrics must be the merge identity");
        });
    }
}
