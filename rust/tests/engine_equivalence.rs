//! The `ServingPolicy`-independent equivalence suite: the event-calendar
//! engine must produce **bit-identical** simulated results — per-request
//! `RequestResult`s, per-shard `ShardStats`, and the SLO tables derived
//! from them — to the per-iteration oracle, across every serving shape the
//! `exp` presets exercise (open-loop traffic × schedulers, chunked prefill
//! with preemption, prefill/decode disaggregation) plus adversarial
//! schedules aimed at the fast-forward boundaries.

use racam::config::{
    racam_paper, ArrivalProcess, ClusterSpec, EngineKind, LengthDist, LlmSpec, Precision,
    SchedulerKind, ServingPolicy, TrafficSpec,
};
use racam::coordinator::{
    ClusterBuilder, Request, Server, ServerReport, SyntheticEngine,
};
use racam::traffic::{generate, SloSummary};
use racam::workloads::RacamSystem;

fn tiny_spec() -> LlmSpec {
    LlmSpec {
        name: "tiny".into(),
        layers: 2,
        hidden: 256,
        heads: 4,
        kv_heads: 4,
        ffn: 512,
        gated_ffn: false,
        vocab: 512,
        prec: Precision::Int8,
    }
}

/// Deterministic-field comparison: everything except host wall clocks,
/// which differ even between two runs of the same engine.  The field
/// coverage lives in one place — [`ServerReport::sim_divergence`] — so
/// every equivalence gate (this suite, the `Server` unit tests, and
/// `exp scale`'s in-run check) sees the same definition of "identical".
fn assert_identical(label: &str, a: &ServerReport, b: &ServerReport) {
    if let Some(d) = a.sim_divergence(b) {
        panic!("{label}: engines diverged: {d}");
    }
    // The SLO grading layer sees the same numbers, so every rendered
    // table cell — the experiments' actual output — matches too.
    let (sa, sb) = (SloSummary::from_report(a), SloSummary::from_report(b));
    assert_eq!(sa.table_row(label), sb.table_row(label), "{label}: SLO row");
    assert_eq!(
        sa.utilization_table("util", false).render(),
        sb.utilization_table("util", false).render(),
        "{label}: group utilization table"
    );
    assert_eq!(
        sa.utilization_table("util", true).render(),
        sb.utilization_table("util", true).render(),
        "{label}: per-shard utilization table"
    );
}

/// Run one cluster spec on both engines over the same stream and compare.
fn check_cluster(label: &str, mut spec: ClusterSpec, stream: &TrafficSpec) {
    let run = |spec: ClusterSpec| {
        let mut coord = ClusterBuilder::new(spec, &racam_paper(), tiny_spec())
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128));
        for req in generate(stream) {
            coord.submit(req);
        }
        coord.run_to_completion().unwrap()
    };
    let mut oracle_spec = spec.clone();
    for g in &mut oracle_spec.groups {
        g.policy = g.policy.with_engine(EngineKind::Oracle);
    }
    for g in &mut spec.groups {
        g.policy = g.policy.with_engine(EngineKind::Calendar);
    }
    let cal = run(spec);
    let ora = run(oracle_spec);
    assert_identical(label, &cal, &ora);
}

fn stream(requests: u64, rate_per_s: f64, lo: u64, hi: u64, deadline_ns: Option<u64>) -> TrafficSpec {
    TrafficSpec {
        seed: 0xE9_01_44,
        requests,
        arrival: ArrivalProcess::Poisson { rate_per_s },
        prompt: LengthDist::Uniform { lo, hi },
        output: LengthDist::Uniform { lo: 4, hi: 24 },
        deadline_ns,
    }
}

/// The `exp traffic` shape: 2 unified shards × every scheduler × a rate
/// straddling capacity, deadlines attached.
#[test]
fn traffic_preset_is_engine_invariant() {
    for kind in [SchedulerKind::Fcfs, SchedulerKind::Bucketed, SchedulerKind::Edf] {
        let mut spec = ClusterSpec::unified(2, 4);
        spec.groups[0].scheduler = kind;
        check_cluster(
            &format!("traffic/{}", kind.label()),
            spec,
            &stream(90, 2_000.0, 64, 768, Some(80_000_000)),
        );
    }
}

/// The `exp prefill` shape: chunked prefill (with and without EDF
/// preemption) under a long-prompt mix — fast-forward must coexist with
/// mid-prefill members and SRPT chunk scheduling.
#[test]
fn prefill_preset_is_engine_invariant() {
    for (sched, policy) in [
        (SchedulerKind::Fcfs, ServingPolicy::whole_prefill()),
        (SchedulerKind::Fcfs, ServingPolicy::chunked(256)),
        (SchedulerKind::Edf, ServingPolicy::chunked(256).with_preemption()),
    ] {
        let mut spec = ClusterSpec::unified(2, 4);
        spec.groups[0].scheduler = sched;
        spec.groups[0].policy = policy;
        check_cluster(
            &format!("prefill/{}/{}", sched.label(), policy.label()),
            spec,
            &stream(70, 1_000.0, 32, 2048, Some(150_000_000)),
        );
    }
}

/// The `exp disagg` shape: prefill shards handing KV caches to decode
/// shards over the serialized link — handoff accounting, role dispatch
/// and the two-wave run must all be engine-invariant.
#[test]
fn disagg_preset_is_engine_invariant() {
    check_cluster(
        "disagg/2p+2d",
        ClusterSpec::disaggregated(2, 2, 2),
        &stream(48, 3_000.0, 64, 1024, None),
    );
}

fn single_server(engine: EngineKind) -> Server<SyntheticEngine> {
    Server::new(
        SyntheticEngine::new(64, 128),
        RacamSystem::new(&racam_paper()),
        tiny_spec(),
        2,
    )
    .with_policy(ServingPolicy::whole_prefill().with_engine(engine))
}

/// Adversarial: an arrival landing **exactly** on a stretch-iteration
/// boundary.  A probe run reads real iteration-boundary timestamps off
/// the simulated clock; the arrivals are then pinned to those exact
/// values (and ±1 ns around them), where an off-by-one in the
/// fast-forward break condition would release the request one iteration
/// early or late and shift every downstream timestamp.
#[test]
fn arrival_exactly_on_a_stretch_boundary_is_engine_invariant() {
    let probe = {
        let mut s = single_server(EngineKind::Oracle);
        s.submit(Request::new(0, vec![1; 64], 600));
        s.run_to_completion().unwrap()
    };
    let r0 = &probe.results[0];
    // Iteration boundaries on the clock: the first-token stamp and a
    // mid-decode point reconstructed from the uniform early-bucket pace.
    let first = r0.sim_first_token_at_ns;
    let pace = (r0.sim_finish_at_ns - r0.sim_first_token_at_ns) / 599.0;
    for (case, arrival) in [
        ("exact-first-token", first as u64),
        ("one-before", (first as u64).saturating_sub(1)),
        ("one-after", first as u64 + 1),
        ("mid-stretch", (first + pace * 97.0) as u64),
    ] {
        let run = |engine: EngineKind| {
            let mut s = single_server(engine);
            s.submit(Request::new(0, vec![1; 64], 600));
            s.submit(Request::new(1, vec![2; 32], 40).at(arrival));
            s.run_to_completion().unwrap()
        };
        let cal = run(EngineKind::Calendar);
        let ora = run(EngineKind::Oracle);
        assert_identical(&format!("boundary/{case}"), &cal, &ora);
    }
}

/// Adversarial: EDF preemption firing mid-stretch, with the deadline read
/// off a probe run so it lands strictly between the victim's first token
/// and its natural completion.
#[test]
fn preemption_mid_stretch_is_engine_invariant() {
    let probe = {
        let mut s = single_server(EngineKind::Oracle);
        s.submit(Request::new(7, vec![3; 48], 300).with_deadline(u64::MAX));
        s.run_to_completion().unwrap()
    };
    let r = &probe.results[0];
    let mid = ((r.sim_first_token_at_ns + r.sim_finish_at_ns) / 2.0) as u64;
    let run = |engine: EngineKind| {
        let mut s = Server::with_scheduler(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
            racam::coordinator::EdfScheduler::new(),
        );
        s.set_policy(ServingPolicy::whole_prefill().with_preemption().with_engine(engine));
        s.submit(Request::new(7, vec![3; 48], 300).with_deadline(mid));
        s.submit(Request::new(8, vec![4; 16], 30).with_deadline(u64::MAX));
        s.run_to_completion().unwrap()
    };
    let cal = run(EngineKind::Calendar);
    let ora = run(EngineKind::Oracle);
    assert_identical("preempt-mid-stretch", &cal, &ora);
    assert_eq!(cal.shards[0].shed, 1, "the deadline must fire mid-decode");
    let victim = cal.results.iter().find(|r| r.id == 7).unwrap();
    assert!(victim.shed && !victim.tokens.is_empty() && victim.tokens.len() < 300);
}

/// Adversarial: a withholding scheduler must hit the same contract bail
/// on both engines (fast-forward must not mask the livelock detection).
#[test]
fn withholding_scheduler_bails_identically_on_both_engines() {
    struct Withholding {
        queue: Vec<Request>,
    }
    impl racam::coordinator::Scheduler for Withholding {
        fn submit(&mut self, req: Request) {
            self.queue.push(req);
        }
        fn pending(&self) -> usize {
            self.queue.len()
        }
        fn next_batch(&mut self, _slots: usize) -> Vec<Request> {
            Vec::new()
        }
    }
    let run = |engine: EngineKind| {
        let mut s = Server::with_scheduler(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
            Withholding { queue: Vec::new() },
        );
        s.set_policy(ServingPolicy::whole_prefill().with_engine(engine));
        s.submit(Request::new(0, vec![1, 2], 4));
        s.submit(Request::new(1, vec![3], 4));
        s.run_to_completion().unwrap_err().to_string()
    };
    let cal = run(EngineKind::Calendar);
    let ora = run(EngineKind::Oracle);
    assert_eq!(cal, ora, "identical contract-violation diagnostics");
    assert!(cal.contains("withheld 2 queued request(s)"), "{cal}");
}

/// Cross-thread gate: the host worker pool (`runtime::executor`) must be
/// invisible to the simulation.  The same cluster × stream on 1 thread,
/// 2 threads, every core, and an oversubscribed pool must produce
/// bit-identical merged reports *and* identical rendered SLO/utilization
/// tables — on the preset whose schedule is hardest to keep deterministic
/// (EDF + chunked prefill + preemption, deadlines attached).
#[test]
fn worker_pool_size_is_simulation_invariant() {
    use racam::runtime::executor;
    let mut spec = ClusterSpec::unified(4, 4);
    spec.groups[0].scheduler = SchedulerKind::Edf;
    spec.groups[0].policy = ServingPolicy::chunked(256).with_preemption();
    let traffic = stream(80, 2_000.0, 64, 768, Some(80_000_000));
    let run = |threads: usize| {
        let mut coord = ClusterBuilder::new(spec.clone(), &racam_paper(), tiny_spec())
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128));
        coord.set_threads(threads);
        for req in generate(&traffic) {
            coord.submit(req);
        }
        coord.run_to_completion().unwrap()
    };
    let base = run(1);
    let mut pools = vec![2, executor::available_parallelism(), 9];
    pools.sort_unstable();
    pools.dedup();
    for t in pools {
        assert_identical(&format!("pool-t{t}"), &run(t), &base);
    }
}

/// The telemetry tentpole's zero-cost contract: attaching a
/// `TraceRecorder` to every shard (and the KV link) must be invisible to
/// the simulation — bit-identical reports to the unrecorded build, on
/// both engines, across cluster shapes (unified FCFS, unified EDF +
/// chunked prefill + preemption, disaggregated) and worker-pool sizes —
/// while actually capturing a non-empty event stream.
#[test]
fn recording_is_simulation_invariant_across_engines_and_pools() {
    use racam::runtime::executor;
    use racam::telemetry::TraceRecorder;
    let shapes: Vec<(&str, ClusterSpec)> = {
        let mut edf = ClusterSpec::unified(2, 4);
        edf.groups[0].scheduler = SchedulerKind::Edf;
        edf.groups[0].policy = ServingPolicy::chunked(256).with_preemption();
        vec![
            ("unified/fcfs", ClusterSpec::unified(2, 4)),
            ("unified/edf+chunk+preempt", edf),
            ("disagg/1p+1d", ClusterSpec::disaggregated(1, 1, 4)),
        ]
    };
    let traffic = stream(60, 2_000.0, 64, 768, Some(80_000_000));
    let mut pools = vec![1, 2, executor::available_parallelism()];
    pools.sort_unstable();
    pools.dedup();
    for engine in [EngineKind::Calendar, EngineKind::Oracle] {
        for (label, shape) in &shapes {
            let mut spec = shape.clone();
            for g in &mut spec.groups {
                g.policy = g.policy.with_engine(engine);
            }
            let plain = {
                let mut coord = ClusterBuilder::new(spec.clone(), &racam_paper(), tiny_spec())
                    .unwrap()
                    .build(|_| SyntheticEngine::new(64, 128));
                for req in generate(&traffic) {
                    coord.submit(req);
                }
                coord.run_to_completion().unwrap()
            };
            for &threads in &pools {
                let mut coord = ClusterBuilder::new(spec.clone(), &racam_paper(), tiny_spec())
                    .unwrap()
                    .build_recorded(
                        |_| SyntheticEngine::new(64, 128),
                        |_| TraceRecorder::new(),
                        TraceRecorder::new(),
                    );
                coord.set_threads(threads);
                for req in generate(&traffic) {
                    coord.submit(req);
                }
                let rep = coord.run_to_completion().unwrap();
                let tag = format!("{label}/{}/recorded-t{threads}", engine.label());
                assert_identical(&tag, &rep, &plain);
                let events: usize = (0..coord.num_shards())
                    .map(|i| coord.shard_recorder(i).events.len())
                    .sum();
                assert!(events > 0, "{tag}: a recorded run must capture events");
                if spec.is_disaggregated() {
                    assert!(
                        !coord.link_recorder().events.is_empty(),
                        "{tag}: handoffs must land on the KV-link track"
                    );
                }
            }
        }
    }
}

/// The exported trace of a recorded run is valid Chrome-trace JSON:
/// `validate_trace` (the same check `tracecheck` runs in CI) accepts it,
/// per-track timestamps are monotonic, spans balance, and the JSON
/// round-trips through the in-tree parser.
#[test]
fn recorded_run_exports_a_valid_chrome_trace() {
    use racam::telemetry::{chrome_trace, validate_trace, TraceRecorder};
    let mut coord =
        ClusterBuilder::new(ClusterSpec::disaggregated(1, 1, 4), &racam_paper(), tiny_spec())
            .unwrap()
            .build_recorded(
                |_| SyntheticEngine::new(64, 128),
                |_| TraceRecorder::new(),
                TraceRecorder::new(),
            );
    for req in generate(&stream(40, 3_000.0, 64, 1024, None)) {
        coord.submit(req);
    }
    coord.run_to_completion().unwrap();
    let mut tracks = Vec::new();
    for i in 0..coord.num_shards() {
        tracks.push((format!("shard {i}"), coord.shard_recorder(i).events.clone()));
    }
    tracks.push(("kv link".to_string(), coord.link_recorder().events.clone()));
    let trace = chrome_trace(&tracks, coord.worker_stats());
    let check = validate_trace(&trace).expect("exported trace must validate");
    assert!(check.events > 0);
    assert!(check.spans > 0, "prefill/decode/KV-wire spans must be present");
    assert!(check.tracks >= tracks.len(), "every simulated track plus workers");
    let reparsed = racam::config::json::parse(&trace.pretty()).expect("round-trips");
    validate_trace(&reparsed).expect("still valid after a JSON round-trip");
}

/// The bucket-schedule cache must not change *what* is priced: identical
/// decode-bucket population and mapping-service hit/miss counters across
/// engines (the satellite's cache-accounting pin, at the cluster level).
#[test]
fn pricing_cache_counters_are_engine_invariant() {
    let run = |engine: EngineKind| {
        let mut spec = ClusterSpec::unified(2, 4);
        spec.groups[0].policy = ServingPolicy::whole_prefill().with_engine(engine);
        let mut coord = ClusterBuilder::new(spec, &racam_paper(), tiny_spec())
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128));
        for req in generate(&stream(60, 2_000.0, 64, 768, None)) {
            coord.submit(req);
        }
        let rep = coord.run_to_completion().unwrap();
        let misses: u64 = coord.services().iter().map(|s| s.misses()).sum();
        let hits: u64 = coord.services().iter().map(|s| s.hits()).sum();
        (rep, misses, hits)
    };
    let (cal, cal_misses, cal_hits) = run(EngineKind::Calendar);
    let (ora, ora_misses, ora_hits) = run(EngineKind::Oracle);
    assert_identical("cache-counters", &cal, &ora);
    assert_eq!(cal_misses, ora_misses, "same unique shapes searched");
    assert_eq!(cal_hits, ora_hits, "same cache-served pricing requests");
}

/// The fault-free identity gate: installing a default (empty)
/// `FaultSpec` must reproduce every serving shape bit-for-bit — the
/// fault plumbing (calendar fault edges, the recovery loop, the
/// per-shard report accumulator) may not perturb a single simulated
/// quantity when no fault is scheduled.  Checked across engines ×
/// worker-pool sizes 1/2/max on the same shapes the recording gate uses.
#[test]
fn empty_fault_spec_is_bit_identical_to_the_fault_free_path() {
    use racam::config::FaultSpec;
    use racam::runtime::executor;
    let shapes: Vec<(&str, ClusterSpec)> = {
        let mut edf = ClusterSpec::unified(2, 4);
        edf.groups[0].scheduler = SchedulerKind::Edf;
        edf.groups[0].policy = ServingPolicy::chunked(256).with_preemption();
        vec![
            ("unified/fcfs", ClusterSpec::unified(2, 4)),
            ("unified/edf+chunk+preempt", edf),
            ("disagg/2p+2d", ClusterSpec::disaggregated(2, 2, 4)),
        ]
    };
    let traffic = stream(60, 2_000.0, 64, 768, Some(80_000_000));
    let mut pools = vec![1, 2, executor::available_parallelism()];
    pools.sort_unstable();
    pools.dedup();
    for engine in [EngineKind::Calendar, EngineKind::Oracle] {
        for (label, shape) in &shapes {
            let mut spec = shape.clone();
            for g in &mut spec.groups {
                g.policy = g.policy.with_engine(engine);
            }
            let run = |threads: usize, faults: Option<FaultSpec>| {
                let mut coord = ClusterBuilder::new(spec.clone(), &racam_paper(), tiny_spec())
                    .unwrap()
                    .build(|_| SyntheticEngine::new(64, 128));
                coord.set_threads(threads);
                if let Some(f) = faults {
                    coord.set_faults(&f).unwrap();
                }
                for req in generate(&traffic) {
                    coord.submit(req);
                }
                coord.run_to_completion().unwrap()
            };
            let plain = run(1, None);
            for &threads in &pools {
                assert_identical(
                    &format!("{label}/{}/empty-faults-t{threads}", engine.label()),
                    &run(threads, Some(FaultSpec::default())),
                    &plain,
                );
            }
        }
    }
}

/// Determinism under chaos: one non-trivial fault schedule (a prefill
/// crash, a brownout, a KV-link outage) on the disaggregated cluster
/// must produce bit-identical merged reports — recovery accounting
/// included, via `sim_divergence`'s `FaultTally` coverage — across
/// calendar/oracle engines and worker-pool sizes 1/2/max.
#[test]
fn faulted_schedule_is_engine_and_pool_invariant() {
    use racam::config::{FaultEvent, FaultSpec};
    use racam::runtime::executor;
    let spec_for = |engine: EngineKind| {
        let mut spec = ClusterSpec::disaggregated(2, 2, 4);
        for g in &mut spec.groups {
            g.policy = g.policy.with_engine(engine);
        }
        spec
    };
    let faults = FaultSpec {
        seed: 11,
        events: vec![
            FaultEvent::ShardCrash { shard: 0, at_ns: 0.0 },
            FaultEvent::Brownout { shard: 1, start_ns: 0.0, end_ns: 1e15, slowdown: 1.5 },
            FaultEvent::LinkOutage { start_ns: 0.0, end_ns: 1e7 },
        ],
        ..FaultSpec::default()
    };
    let traffic = stream(40, 3_000.0, 64, 512, None);
    let run = |engine: EngineKind, threads: usize| {
        let mut coord = ClusterBuilder::new(spec_for(engine), &racam_paper(), tiny_spec())
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128));
        coord.set_threads(threads);
        coord.set_faults(&faults).unwrap();
        for req in generate(&traffic) {
            coord.submit(req);
        }
        coord.run_to_completion().unwrap()
    };
    let base = run(EngineKind::Calendar, 1);
    let slo = SloSummary::from_report(&base);
    assert!(slo.retries > 0, "the crashed prefill shard's share must be requeued");
    assert_eq!(slo.capacity_timeline.len(), 1, "one crash on the capacity timeline");
    let mut pools = vec![1, 2, executor::available_parallelism()];
    pools.sort_unstable();
    pools.dedup();
    for engine in [EngineKind::Calendar, EngineKind::Oracle] {
        for &threads in &pools {
            assert_identical(&format!("chaos/{}/t{threads}", engine.label()), &run(engine, threads), &base);
        }
    }
}
