//! Integration tests across the three layers: the AOT-compiled JAX/Pallas
//! artifacts (L1/L2) loaded through the PJRT runtime (behind the `pjrt`
//! feature), cross-checked against the functional bit-serial simulator and
//! the analytical models (L3), plus the multi-shard serving coordinator
//! over the shared mapping service and the open-loop traffic pipeline
//! (generator → schedulers → SLO grading, with async mid-run admission).
//!
//! The PJRT tests require `make artifacts` to have run; they skip (with a
//! note) when the artifacts are missing so `cargo test` stays usable on a
//! fresh checkout.

use racam::config::{racam_paper, racam_tiny, ClusterSpec, MatmulShape, Precision, ShardRole};
use racam::coordinator::{ClusterBuilder, Request, SyntheticEngine};
use racam::mapping::{HwModel, MappingEngine, MappingService};
use racam::pim::{gemm_reference, BlockExecutor};

#[cfg(feature = "pjrt")]
use racam::coordinator::{HloDecodeEngine, Server, TokenEngine};
#[cfg(feature = "pjrt")]
use racam::runtime::{ArtifactSet, Runtime};
#[cfg(feature = "pjrt")]
use racam::workloads::RacamSystem;

#[cfg(feature = "pjrt")]
fn artifacts() -> Option<ArtifactSet> {
    let set = ArtifactSet::discover();
    if set.present() {
        Some(set)
    } else {
        eprintln!("skipping integration test: run `make artifacts` first");
        None
    }
}

fn lcg(seed: &mut u64) -> i64 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    (*seed >> 33) as i64
}

fn rand_mat(len: usize, bound: i64, seed: &mut u64) -> Vec<i64> {
    (0..len).map(|_| lcg(seed).rem_euclid(2 * bound) - bound).collect()
}

/// The headline composition check: the same int8 GEMM computed three ways —
/// (1) the AOT-lowered Pallas kernel executed via PJRT, (2) the functional
/// bit-serial locality-buffer simulator, (3) a plain scalar reference —
/// must agree integer-for-integer.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_oracle_matches_bit_serial_simulator() {
    let Some(set) = artifacts() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");

    for (m, k, n) in [(16usize, 64usize, 8usize), (64, 256, 32)] {
        let module = rt.load_hlo_text(&set.gemm(m, k, n)).expect("load gemm artifact");
        let mut seed = 0xC0FFEE ^ (m as u64);
        let x = rand_mat(m * k, 128, &mut seed);
        let w = rand_mat(k * n, 128, &mut seed);

        // (1) PJRT execution of the Pallas-lowered HLO.
        let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
        let wi: Vec<i32> = w.iter().map(|&v| v as i32).collect();
        let got_pjrt = module
            .run_i32(&[(&xi, &[m as i64, k as i64]), (&wi, &[k as i64, n as i64])])
            .expect("execute gemm artifact");

        // (2) Functional bit-serial execution through the locality buffer.
        let mut exec = BlockExecutor::new(&racam_tiny());
        let (got_sim, stats) = exec.gemm(&x, &w, m, k, n, Precision::Int8);

        // (3) Scalar reference.
        let want = gemm_reference(&x, &w, m, k, n);

        assert_eq!(got_sim, want, "bit-serial simulator mismatch at {m}x{k}x{n}");
        let got_pjrt64: Vec<i64> = got_pjrt.iter().map(|&v| v as i64).collect();
        assert_eq!(got_pjrt64, want, "PJRT oracle mismatch at {m}x{k}x{n}");
        assert_eq!(stats.macs, (m * k * n) as u64);
    }
}

/// The transformer block artifact runs and is numerically sane (finite,
/// non-trivial, deterministic).
#[cfg(feature = "pjrt")]
#[test]
fn transformer_block_artifact_executes() {
    let Some(set) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let module = rt.load_hlo_text(&set.transformer_block()).unwrap();

    let (s, h, f) = (16usize, 64usize, 128usize);
    let x: Vec<f32> = (0..s * h).map(|i| ((i % 23) as f32 - 11.0) * 0.1).collect();
    let mut seed = 7u64;
    let wq: Vec<i32> = rand_mat(h * 3 * h, 64, &mut seed).iter().map(|&v| v as i32).collect();
    let wo: Vec<i32> = rand_mat(h * h, 64, &mut seed).iter().map(|&v| v as i32).collect();
    let w1: Vec<i32> = rand_mat(h * f, 64, &mut seed).iter().map(|&v| v as i32).collect();
    let w2: Vec<i32> = rand_mat(f * h, 64, &mut seed).iter().map(|&v| v as i32).collect();

    let run = || -> Vec<f32> {
        let lits = vec![
            xla::Literal::vec1(&x[..]).reshape(&[s as i64, h as i64]).unwrap(),
            xla::Literal::vec1(&wq[..]).reshape(&[h as i64, 3 * h as i64]).unwrap(),
            xla::Literal::vec1(&wo[..]).reshape(&[h as i64, h as i64]).unwrap(),
            xla::Literal::vec1(&w1[..]).reshape(&[h as i64, f as i64]).unwrap(),
            xla::Literal::vec1(&w2[..]).reshape(&[f as i64, h as i64]).unwrap(),
        ];
        module.run_literals(&lits).unwrap().to_vec::<f32>().unwrap()
    };
    let out1 = run();
    let out2 = run();
    assert_eq!(out1.len(), s * h);
    assert_eq!(out1, out2, "block must be deterministic");
    assert!(out1.iter().all(|v| v.is_finite()));
    let spread = out1.iter().cloned().fold(f32::MIN, f32::max)
        - out1.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 0.1, "output suspiciously flat: spread {spread}");
}

/// End-to-end serving: HLO decode engine generates real tokens under the
/// coordinator, deterministically, with simulated RACAM accounting.
#[cfg(feature = "pjrt")]
#[test]
fn serving_loop_generates_tokens_via_pjrt() {
    let Some(set) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let module = rt.load_hlo_text(&set.decode_step()).unwrap();
    let engine = HloDecodeEngine::new(module, 64, 256);

    let spec = racam::config::gpt3_6_7b();
    let mut server = Server::new(engine, RacamSystem::new(&racam_paper()), spec, 2);
    for id in 0..3 {
        server.submit(Request::new(id, vec![id as u32 + 1, 42, 7], 12));
    }
    let report = server.run_to_completion().unwrap();
    assert_eq!(report.results.len(), 3);
    assert_eq!(report.total_tokens, 36);
    for r in &report.results {
        assert_eq!(r.tokens.len(), 12);
        assert!(r.tokens.iter().all(|&t| t < 256));
        assert!(r.sim_total_ns > r.sim_ttft_ns);
    }
    // Same prompt → same first token when re-run from scratch (greedy,
    // deterministic baked weights).
    let rt2 = Runtime::cpu().unwrap();
    let module2 = rt2.load_hlo_text(&set.decode_step()).unwrap();
    let mut engine2 = HloDecodeEngine::new(module2, 64, 256);
    let x = engine2.embed_prompt(&[1, 42, 7]);
    let (_, tok) = engine2.step(&x).unwrap();
    assert_eq!(tok, report.results[0].tokens[0]);
}

/// The analytical model and the functional simulator agree on operation
/// counts: row accesses per pass are exactly 4n (the O(n) claim).
#[test]
fn analytical_row_accesses_match_functional_counts() {
    let (m, k, n) = (4usize, 200usize, 3usize);
    let mut seed = 5;
    let x = rand_mat(m * k, 128, &mut seed);
    let w = rand_mat(k * n, 128, &mut seed);
    let hw = racam_tiny();
    let mut exec = BlockExecutor::new(&hw);
    let (got, stats) = exec.gemm(&x, &w, m, k, n, Precision::Int8);
    assert_eq!(got, gemm_reference(&x, &w, m, k, n));
    assert_eq!(
        stats.row_accesses,
        stats.passes * racam::pim::isa::mul_row_accesses(8, true),
        "functional row traffic must equal the analytical 4n per pass"
    );
}

/// Mapping search sanity on the paper hardware (used by every experiment):
/// the parallel search is fast, consistent, and bit-identical to the
/// serial reference.
#[test]
fn search_on_paper_hw_is_fast_and_consistent() {
    let engine = MappingEngine::new(HwModel::new(&racam_paper()));
    let shape = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
    #[allow(clippy::disallowed_methods)] // test-only timing assertion
    let t0 = std::time::Instant::now();
    let r = engine.search(&shape).expect("GEMM space evaluates");
    let elapsed = t0.elapsed();
    // The pruned default examines the whole space (evaluated + pruned).
    assert_eq!(r.examined(), 1458);
    // Paper §7: 2–3 s on 16 cores; we require < 5 s.
    assert!(elapsed.as_secs_f64() < 5.0, "search took {elapsed:?}");
    assert!(r.best.total_ns() > 0.0);

    let serial = engine.search_serial(&shape).expect("GEMM space evaluates");
    assert_eq!(r.best.mapping, serial.best.mapping);
    assert_eq!(r.best.total_ns().to_bits(), serial.best.total_ns().to_bits());
    assert!(serial.spread() > 1.0);
}

/// Multi-shard serving over one shared mapping service: every request
/// completes, the merged report is id-sorted, and a shape repeated across
/// shards is searched exactly once system-wide.
#[test]
fn multi_shard_coordinator_shares_one_mapping_cache() {
    let spec = racam::config::gpt3_6_7b();
    let service = MappingService::for_config(&racam_paper());
    let mut coord = ClusterBuilder::with_spec_and_services(
        ClusterSpec::unified(3, 2),
        spec,
        vec![service.clone(); 3],
    )
    .unwrap()
    .build(|_| SyntheticEngine::new(64, 128));
    for id in 0..6 {
        coord.submit(Request::new(id, vec![1, 2, 3], 4));
    }
    let report = coord.run_to_completion().unwrap();
    assert_eq!(report.results.len(), 6);
    assert_eq!(report.total_tokens, 24);
    for (i, r) in report.results.iter().enumerate() {
        assert_eq!(r.id, i as u64);
    }
    assert_eq!(report.shards.len(), 3);

    // All shards priced identical prompt lengths and context buckets:
    // misses == unique shapes means no shard ever re-searched a shape.
    assert_eq!(service.misses(), service.cache_len() as u64);
    assert!(service.hits() > 0, "later shards must be served from the shared cache");
}

/// End-to-end open-loop serving: a generated Poisson stream plays through
/// the coordinator under all three admission policies, every request
/// completes, and the SLO layer grades each run — while a live intake
/// admits extra requests mid-run.
#[test]
fn open_loop_traffic_serves_under_every_scheduler() {
    use racam::config::{ArrivalProcess, LengthDist, SchedulerKind, TrafficSpec};
    use racam::traffic::{generate, SloSummary};

    let spec = racam::config::gpt3_6_7b();
    let traffic = TrafficSpec {
        seed: 11,
        requests: 8,
        arrival: ArrivalProcess::Bursty { rate_per_s: 400.0, burst: 4 },
        prompt: LengthDist::Uniform { lo: 2, hi: 12 },
        output: LengthDist::Uniform { lo: 1, hi: 4 },
        deadline_ns: Some(1_000_000_000),
    };
    let stream = generate(&traffic);
    let service = MappingService::for_config(&racam_paper());

    fn serve(
        service: &MappingService,
        spec: &racam::config::LlmSpec,
        stream: &[racam::coordinator::Request],
        scheduler: SchedulerKind,
    ) -> SloSummary {
        let mut cluster = ClusterSpec::unified(2, 2);
        cluster.groups[0].scheduler = scheduler;
        let mut coord = ClusterBuilder::with_spec_and_services(
            cluster,
            spec.clone(),
            vec![service.clone(); 2],
        )
        .unwrap()
        .build(|_| SyntheticEngine::new(64, 128));
        for r in stream {
            coord.submit(r.clone());
        }
        // Async admission: one request shows up only after the run starts.
        let mut intake = coord.intake();
        #[allow(clippy::disallowed_methods)] // test harness thread
        let late = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            assert!(intake.submit(Request::new(500, vec![1, 2], 2)));
        });
        let report = coord.run_to_completion().unwrap();
        late.join().unwrap();
        assert_eq!(report.results.len(), stream.len() + 1);
        assert!(report.results.iter().any(|r| r.id == 500 && r.tokens.len() == 2));
        SloSummary::from_report(&report)
    }

    let fcfs = serve(&service, &spec, &stream, SchedulerKind::Fcfs);
    let bucketed = serve(&service, &spec, &stream, SchedulerKind::Bucketed);
    let edf = serve(&service, &spec, &stream, SchedulerKind::Edf);
    for (name, s) in [("fcfs", &fcfs), ("bucketed", &bucketed), ("edf", &edf)] {
        assert_eq!(s.requests, 9, "{name}");
        assert!(s.ttft.p50 > 0.0, "{name}");
        assert!(s.e2e.p99 >= s.e2e.p50, "{name}");
        assert!(s.throughput_tokens_per_s > 0.0, "{name}");
        assert!(s.goodput_tokens_per_s <= s.throughput_tokens_per_s + 1e-9, "{name}");
        assert_eq!(s.shed_requests, 0, "{name}: default policy never sheds");
    }
    // Identical shapes across all three runs: the shared cache means the
    // second and third schedulers searched nothing new.
    assert_eq!(service.misses(), service.cache_len() as u64);
}

/// The event-driven serving policy end-to-end: a long-prompt mixed stream
/// served (a) whole-prefill and (b) chunked + deadline-preempting, through
/// the multi-shard coordinator.  Chunking must cut the short requests'
/// first-token tail, never change what is generated for completed work,
/// and preemption must surface shed work in the SLO summary.
#[test]
fn chunked_prefill_and_preemption_end_to_end() {
    use racam::config::{SchedulerKind, ServingPolicy};
    use racam::traffic::{ttft_percentiles_where, SloSummary};

    let spec = racam::config::gpt3_6_7b();
    let service = MappingService::for_config(&racam_paper());

    // One shard so every short queues behind the long prompt's prefill.
    let serve = |policy: ServingPolicy| {
        let mut cluster = ClusterSpec::unified(1, 2);
        cluster.groups[0].policy = policy;
        let mut coord = ClusterBuilder::with_spec_and_services(
            cluster,
            spec.clone(),
            vec![service.clone()],
        )
        .unwrap()
        .build(|_| SyntheticEngine::new(64, 128));
        // A 2048-token prompt and a short request arriving together, three
        // times over, well spaced.
        for i in 0..3u64 {
            let at = 1 + i * 10_000_000_000;
            coord.submit(Request::new(2 * i, vec![1; 2048], 2).at(at));
            coord.submit(Request::new(2 * i + 1, vec![2; 16], 2).at(at));
        }
        coord.run_to_completion().unwrap()
    };
    let whole = serve(ServingPolicy::whole_prefill());
    let chunked = serve(ServingPolicy::chunked(256));
    // Generation is schedule-independent.
    let tok = |rep: &racam::coordinator::ServerReport| {
        rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
    };
    assert_eq!(tok(&whole), tok(&chunked));
    // Short-request TTFT tail: chunked must undercut whole-prefill.
    let short = |rep: &racam::coordinator::ServerReport| {
        ttft_percentiles_where(rep, |r| r.prompt_tokens <= 256).p95
    };
    assert!(
        short(&chunked) < short(&whole),
        "chunked short p95 {} must beat whole {}",
        short(&chunked),
        short(&whole)
    );
    assert!(chunked.shards[0].prefill_chunks > whole.shards[0].prefill_chunks);

    // Preemption under EDF: impossible deadlines are shed and reported.
    let mut cluster = ClusterSpec::unified(1, 2);
    cluster.groups[0].scheduler = SchedulerKind::Edf;
    cluster.groups[0].policy = ServingPolicy::interactive();
    let mut coord =
        ClusterBuilder::with_spec_and_services(cluster, spec, vec![service.clone()])
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128));
    coord.submit(Request::new(0, vec![1; 16], 4).with_deadline(u64::MAX));
    coord.submit(Request::new(1, vec![2; 16], 4).with_deadline(1));
    let report = coord.run_to_completion().unwrap();
    let slo = SloSummary::from_report(&report);
    assert_eq!(slo.shed_requests, 1, "the expired-deadline request must be shed");
    assert!(report.results.iter().any(|r| r.id == 1 && r.shed));
    assert!(report.results.iter().any(|r| r.id == 0 && !r.shed && r.tokens.len() == 4));
}

/// Prefill/decode disaggregation end-to-end, from a JSON cluster spec (the
/// `racam serve --cluster` path): a role-split cluster with explicit
/// channel shares serves an open-loop stream, every request completes with
/// generation identical to a unified cluster, decode shards charge nonzero
/// KV-transfer time, and the per-group SLO view separates the roles.
#[test]
fn disaggregated_cluster_from_json_end_to_end() {
    use racam::config::{ArrivalProcess, LengthDist, TrafficSpec};
    use racam::traffic::{generate, SloSummary};

    let spec = racam::config::gpt3_6_7b();
    let cluster_json = r#"{
        "kv_link_gbps": 64,
        "groups": [
            {"name": "prefill", "count": 2, "role": "prefill", "scheduler": "fcfs",
             "max_batch": 2, "channels": 4, "policy": {}},
            {"name": "decode", "count": 2, "role": "decode", "scheduler": "fcfs",
             "max_batch": 2, "channels": 4, "policy": {}}
        ]
    }"#;
    let cluster = ClusterSpec::from_json(cluster_json).unwrap();
    assert!(cluster.is_disaggregated());

    let stream = generate(&TrafficSpec {
        seed: 23,
        requests: 10,
        arrival: ArrivalProcess::Poisson { rate_per_s: 300.0 },
        prompt: LengthDist::Uniform { lo: 8, hi: 48 },
        output: LengthDist::Uniform { lo: 2, hi: 5 },
        deadline_ns: None,
    });

    let serve = |cluster: ClusterSpec| {
        let mut coord = ClusterBuilder::new(cluster, &racam_paper(), spec.clone())
            .unwrap()
            .build(|_| SyntheticEngine::new(64, 128));
        for r in &stream {
            coord.submit(r.clone());
        }
        coord.run_to_completion().unwrap()
    };
    let disagg = serve(cluster);
    let unified = serve(ClusterSpec::unified(4, 2));

    assert_eq!(disagg.results.len(), stream.len());
    let tok = |rep: &racam::coordinator::ServerReport| {
        rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
    };
    assert_eq!(tok(&disagg), tok(&unified), "topology must not change generation");

    // Decode shards paid the KV link; prefill shards sent every request.
    let kv: f64 = disagg
        .shards
        .iter()
        .filter(|s| s.role == ShardRole::Decode)
        .map(|s| s.kv_transfer_ns)
        .sum();
    assert!(kv > 0.0, "decode shards must charge KV-transfer time");
    for s in &disagg.shards {
        match s.role {
            ShardRole::Decode => assert_eq!(s.prefill_chunks, 0, "shard {}", s.shard),
            _ => assert_eq!(s.tokens, 0, "shard {}", s.shard),
        }
    }
    let slo = SloSummary::from_report(&disagg);
    assert_eq!(slo.handoffs, stream.len());
    assert!((slo.kv_transfer_ns - kv).abs() < 1e-9);
    let groups = slo.utilization_table("by group", false);
    assert_eq!(groups.num_rows(), 2, "one utilization row per role group");
}
