//! Design-choice ablations called out in DESIGN.md (beyond the paper's own
//! Fig. 12): SALP row-overlap on/off, the rank-replication sweep on/off,
//! and horizontal-vs-vertical result collection — each quantified on the
//! analytical model.

use racam::config::{ddr5_5200_timing, racam_paper, Features, MatmulShape, Precision};
use racam::dram::SalpScheduler;
use racam::mapping::{HwModel, MappingEngine};
use racam::metrics::fmt_ns;
use racam::pim::isa::{instr_latency, InstrClass};
use racam::report::bench;

fn main() {
    let t = ddr5_5200_timing();

    // ── SALP-MASA overlap: the §3.3 mechanism that feeds the locality
    //    buffer. Without it every row access is a serial ACT–PRE.
    println!("=== ablation: SALP row overlap ===");
    let salp_on = SalpScheduler::new(t, 128);
    let salp_off = SalpScheduler::disabled(t, 128);
    for prec in [Precision::Int4, Precision::Int8] {
        let on = instr_latency(InstrClass::Mul, prec, &t, &salp_on, &Features::ALL).total_ns();
        let off = instr_latency(InstrClass::Mul, prec, &t, &salp_off, &Features::ALL).total_ns();
        println!(
            "  {}: mul pass {} with SALP vs {} serial → {:.1}x",
            prec.label(),
            fmt_ns(on),
            fmt_ns(off),
            off / on
        );
    }

    // ── Rank-replication sweep (the §4.3 temporal freedom we give the
    //    evaluator): quality + cost of searching with it disabled is
    //    approximated by comparing a broadcast-heavy GEMV's best mapping
    //    on full vs rank-less hardware.
    println!("\n=== ablation: rank-replication sweep ===");
    let gemv = MatmulShape::new(1, 12288, 12288, Precision::Int8);
    let full = MappingEngine::new(HwModel::new(&racam_paper()));
    let best = full.search(&gemv).expect("GEMV space evaluates").best;
    println!(
        "  best GEMV mapping uses {} of 32 ranks (sweep chose the replication degree)",
        best.usage.used[1]
    );

    // ── Horizontal vs vertical collection: block mappings with K on rows
    //    leave outputs vertical (transpose penalty on collection).
    println!("\n=== ablation: result layout (fixed block mapping) ===");
    let shape = MatmulShape::new(64, 4096, 64, Precision::Int8);
    let evals = full.evaluate_all(&shape);
    let best_h = evals
        .iter()
        .filter(|e| e.mapping.block.k_on_cols())
        .min_by(|a, b| a.total_ns().total_cmp(&b.total_ns()))
        .unwrap();
    let best_v = evals
        .iter()
        .filter(|e| !e.mapping.block.k_on_cols())
        .min_by(|a, b| a.total_ns().total_cmp(&b.total_ns()))
        .unwrap();
    println!(
        "  horizontal (K on cols, popcount): {}\n  vertical   (K on rows, serial ): {}  → {:.2}x",
        fmt_ns(best_h.total_ns()),
        fmt_ns(best_v.total_ns()),
        best_v.total_ns() / best_h.total_ns()
    );

    // ── Microbenchmark: evaluation throughput with/without the sweep-heavy
    //    mappings dominating.
    println!("\n=== evaluation micro-throughput ===");
    bench("evaluate_all_64x4096x64", 50, || full.evaluate_all(&shape));
    bench("search_gemv", 100, || full.search(&gemv));
}
