//! Bench for paper Figs. 12–14 and 17: regenerates the ablation,
//! PE-count-sensitivity, precision-sensitivity and latency-breakdown
//! tables, and times the ablated-hardware re-search (each feature set
//! re-runs the full mapping space).

use racam::config::{racam_paper, Features, MatmulShape, Precision};
use racam::mapping::{HwModel, MappingEngine};
use racam::report::bench;

fn main() {
    for id in ["fig12", "fig13", "fig14", "fig17"] {
        println!("=== {id} ===");
        for t in racam::experiments::run(id).expect(id) {
            println!("{}", t.render());
        }
    }

    println!("=== ablated-search timing (1458-candidate GEMM space each) ===");
    let shape = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
    for f in [Features::ALL, Features::NO_PR, Features::NO_PR_BU, Features::NO_PR_BU_LB] {
        let mut hw = racam_paper();
        hw.features = f;
        let engine = MappingEngine::new(HwModel::new(&hw));
        bench(&format!("search_{}", f.label()), 20, || engine.search(&shape));
    }
}
