//! Hot-path benchmark of the functional bit-serial simulator — the target
//! of the §Perf optimization pass (word-packed bit-plane operations).

use racam::config::{racam_tiny, Precision};
use racam::pim::{bitplane, BlockExecutor, LocalityBuffer, PeArray, PopcountUnit};
use racam::report::bench;

fn main() {
    let width = 128u32;

    println!("=== bit-plane primitives ===");
    let vals: Vec<u64> = (0..128).map(|i| (i * 37 + 11) % 256).collect();
    bench("to_planes_int8_128", 20_000, || bitplane::to_planes(&vals, 8, width));
    let planes = bitplane::to_planes(&vals, 8, width);
    bench("from_planes_int8_128", 20_000, || bitplane::from_planes(&planes, 128));

    println!("\n=== locality-buffer multiply (Fig. 6 schedule) ===");
    let op = bitplane::to_planes(&vals, 8, width);
    let mut lb = LocalityBuffer::new(17, width);
    let mut pes = PeArray::new(width);
    bench("lb_multiply_int8_128lanes", 5_000, || lb.multiply(&mut pes, &op, &op));

    println!("\n=== popcount reduction ===");
    let prod = bitplane::to_planes(&vals, 16, width);
    bench("popcount_reduce_16planes", 50_000, || {
        let mut unit = PopcountUnit::new(width);
        for (i, p) in prod.iter().enumerate() {
            unit.consume_slice(p, width, i as u32);
        }
        unit.sum()
    });

    println!("\n=== end-to-end block executor GEMMs ===");
    let hw = racam_tiny();
    for (m, k, n) in [(2usize, 64usize, 2usize), (4, 256, 4), (8, 512, 8)] {
        let x: Vec<i64> = (0..m * k).map(|i| (i as i64 % 255) - 127).collect();
        let w: Vec<i64> = (0..k * n).map(|i| ((i * 7) as i64 % 255) - 127).collect();
        let mut ex = BlockExecutor::new(&hw);
        let iters = (400 / (m * n)).max(10);
        bench(&format!("gemm_{m}x{k}x{n}_int8"), iters, || {
            ex.gemm(&x, &w, m, k, n, Precision::Int8)
        });
    }
}
