//! Bench for paper Figs. 9–11: regenerates the end-to-end throughput,
//! prefill/decode and performance-per-mm² tables, and times the full
//! evaluation pipeline (LLM parse → mapping search → latency roll-up).

use racam::config::{gpt3_6_7b, racam_paper, Scenario};
use racam::report::bench;
use racam::workloads::{e2e_latency, RacamSystem};

fn main() {
    for id in ["fig9", "fig10", "fig11"] {
        println!("=== {id} ===");
        for t in racam::experiments::run(id).expect(id) {
            println!("{}", t.render());
        }
    }

    println!("=== evaluation pipeline timing ===");
    // Cold: every kernel shape searched from scratch.
    bench("e2e_gpt3_6.7B_codegen_cold", 10, || {
        let sys = RacamSystem::new(&racam_paper());
        e2e_latency(&sys, &gpt3_6_7b(), &Scenario::CODE_GENERATION).expect("paper kernels map")
    });
    // Warm: mapping cache reused across calls (the paper's amortized mode).
    let sys = RacamSystem::new(&racam_paper());
    e2e_latency(&sys, &gpt3_6_7b(), &Scenario::CODE_GENERATION).expect("paper kernels map");
    bench("e2e_gpt3_6.7B_codegen_warm_cache", 50, || {
        e2e_latency(&sys, &gpt3_6_7b(), &Scenario::CODE_GENERATION).expect("paper kernels map")
    });
}
