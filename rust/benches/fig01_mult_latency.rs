//! Bench for paper Fig. 1: regenerates the multiplication-latency table and
//! times the functional locality-buffer multiply (the simulator's inner
//! loop) at each precision.

use racam::config::racam_tiny;
use racam::pim::{bitplane, BlockExecutor};
use racam::report::bench;

fn main() {
    println!("=== Fig.1 — integer multiplication latency ===");
    for t in racam::experiments::run("fig1").expect("fig1") {
        println!("{}", t.render());
    }

    println!("=== functional SIMD multiply pass (128 lanes) ===");
    let width = 128u32;
    for bits in [2usize, 4, 8] {
        let vals: Vec<u64> = (0..128).map(|i| (i * 37) % (1 << bits)).collect();
        let op1 = bitplane::to_planes(&vals, bits, width);
        let op2 = bitplane::to_planes(&vals, bits, width);
        let mut lb = racam::pim::LocalityBuffer::new(17, width);
        let mut pes = racam::pim::PeArray::new(width);
        bench(&format!("lb_multiply_int{bits}"), 2000, || {
            lb.multiply(&mut pes, &op1, &op2)
        });
    }

    println!("=== functional int8 GEMM through the block executor ===");
    let hw = racam_tiny();
    let (m, k, n) = (4usize, 128usize, 4usize);
    let x: Vec<i64> = (0..m * k).map(|i| (i as i64 % 255) - 127).collect();
    let w: Vec<i64> = (0..k * n).map(|i| ((i * 3) as i64 % 255) - 127).collect();
    let mut ex = BlockExecutor::new(&hw);
    bench("block_executor_4x128x4_int8", 200, || {
        ex.gemm(&x, &w, m, k, n, racam::config::Precision::Int8)
    });
}
