//! Bench for paper Figs. 15–16: regenerates the mapping-sensitivity
//! scatter and the GEMM/GEMV size-sensitivity tables, and times the
//! exhaustive mapping search itself against the paper's §7 claims
//! (~1 s GEMV, 2–3 s GEMM on a 16-core CPU; each candidate evaluation in
//! microseconds).

use racam::config::{racam_paper, MatmulShape, Precision};
use racam::mapping::{HwModel, MappingEngine};
use racam::report::bench;

fn main() {
    for id in ["fig15", "fig16"] {
        println!("=== {id} ===");
        let tables = racam::experiments::run(id).expect(id);
        // fig15's scatter is 1458 rows; print only the summary table.
        println!("{}", tables[0].render());
    }

    let engine = MappingEngine::new(HwModel::new(&racam_paper()));
    let gemm = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
    let gemv = MatmulShape::new(1, 12288, 12288, Precision::Int8);

    println!("=== mapping search timing (paper §7) ===");
    let pruned = engine.search(&gemm).expect("GEMM evaluates");
    let r = bench("search_gemm_1458_candidates_pruned", 50, || engine.search(&gemm));
    println!(
        "    → pruning skipped {} of {} candidates (winner bit-identical to serial); \
         {:.2} µs per *evaluated* candidate",
        pruned.pruned,
        pruned.examined(),
        r.p50_ns / 1e3 / pruned.candidates.max(1) as f64
    );
    let rx = bench("search_gemm_1458_candidates_exhaustive", 50, || engine.search_exhaustive(&gemm));
    println!(
        "    → {:.2} µs per candidate evaluation, exhaustive (paper: 'within microseconds')",
        rx.p50_ns / 1e3 / 1458.0
    );
    // Serial reference: same winner bit-for-bit, single-threaded.
    bench("search_gemm_1458_candidates_serial", 50, || engine.search_serial(&gemm));
    bench("search_gemv_192_candidates_pruned", 200, || engine.search(&gemv));
    bench("evaluate_all_gemm (scatter dump)", 20, || engine.evaluate_all(&gemm));

    // Cached (amortized) mode through the shared service.
    let cached = MappingEngine::new(HwModel::new(&racam_paper()));
    cached.search_cached(&gemm);
    bench("search_gemm_cached", 1000, || cached.search_cached(&gemm));
}
