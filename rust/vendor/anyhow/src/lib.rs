//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so the
//! workspace builds fully offline.  It implements exactly the subset the
//! `racam` crate uses:
//!
//! * [`Error`] — an opaque error value convertible from any
//!   `std::error::Error + Send + Sync + 'static` (so `?` works on `io::Error`,
//!   parse errors, the in-tree `JsonError`, …);
//! * [`Result<T>`] with the `Error` default;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros (format-string style);
//! * the [`Context`] extension trait (`.context(..)` / `.with_context(..)`)
//!   on both `Result` and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` itself — that is what keeps the blanket `From`
//! conversion coherent.

use std::error::Error as StdError;
use std::fmt;

/// Opaque error: a message plus an optional wrapped source error.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend context, `anyhow`-style (`context: original message`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause, when this error wraps one.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            // `{:#}` walks the cause chain below the wrapped error's own
            // message (which `msg` already contains).
            let mut cause = self.source.as_ref().and_then(|e| e.source());
            while let Some(c) = cause {
                write!(f, ": {c}")?;
                cause = c.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source.as_ref().and_then(|e| e.source());
        while let Some(c) = cause {
            write!(f, "\ncaused by: {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // std error converts via the blanket From
        ensure!(n < 100, "{n} is too large");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
        assert_eq!(parse("400").unwrap_err().to_string(), "400 is too large");
    }

    #[test]
    fn bail_formats() {
        fn f() -> Result<()> {
            bail!("bad value '{}'", 7)
        }
        assert_eq!(f().unwrap_err().to_string(), "bad value '7'");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("reading cache").unwrap_err();
        assert_eq!(e.to_string(), "reading cache: boom");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
