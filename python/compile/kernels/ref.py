"""Pure-jnp oracle for the Pallas kernel — the correctness ground truth
(pytest checks `quant_gemm` against this exactly, integer-for-integer)."""

import jax.numpy as jnp


def quant_gemm_ref(x, w):
    """Reference int GEMM with int32 accumulation."""
    return jnp.dot(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def quantize_ref(x, scale):
    return jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int32)


def dequantize_ref(q, scale):
    return q.astype(jnp.float32) * scale
