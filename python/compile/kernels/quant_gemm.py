"""L1 — Pallas quantized-GEMM kernel: RACAM's compute hot-spot on TPU terms.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): RACAM keeps the
multiplicand resident in a per-bank locality buffer and streams the
multiplier past it so every operand bit crosses the expensive interface
once.  The TPU analogue keeps the weight block resident in VMEM across the
K-loop (the BlockSpec index map below re-uses the block), streams
activation blocks through, and accumulates in the revisited output block —
BlockSpec plays the role of RACAM's hierarchical mapping, VMEM residency
the role of the locality buffer, and the MXU-style int8→int32 dot the role
of the per-column bit-serial PE array.

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; numerics are what we validate here (real-TPU perf is
estimated analytically in DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile: bm×bk + bk×bn + bm×bn int32 words ≈ 3 KB at (16,32,16)
# — far under a real core's ~16 MB VMEM; sized small so interpret-mode
# tests stay fast while exercising multi-step grids.
BLOCK_M = 16
BLOCK_K = 32
BLOCK_N = 16


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One grid step: accumulate x_block @ w_block into the output block.

    The output block is revisited across the K grid dimension (RACAM's
    popcount accumulator); the weight block for a given (n, k) is reused
    across the M grid dimension (RACAM's locality-buffer reuse).
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )


def _pad_to(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def quant_gemm(x, w, bm=BLOCK_M, bk=BLOCK_K, bn=BLOCK_N):
    """int8-range integer GEMM with int32 accumulation.

    `x`: [M, K] int32 (values in int8 range), `w`: [K, N] int32.
    Returns [M, N] int32.  Shapes are zero-padded up to block multiples.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)

    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # Weight block depends only on (j, kk): reused across i — the
            # locality-buffer analogue.
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def quantize(x, scale):
    """f32 → int8-range int32 with symmetric scale."""
    return jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int32)


def dequantize(q, scale):
    """int32 accumulator → f32."""
    return q.astype(jnp.float32) * scale
