"""L2 — the JAX model: a quantized transformer block and the tiny recurrent
decode step, both routing every matmul through the L1 Pallas kernel.

These are the computations AOT-lowered to `artifacts/*.hlo.txt` and
executed from Rust via PJRT (the serving path never runs Python).  They use
int8 weight quantization like the paper's Table 3 workloads; activations
are quantized per-tensor before each GEMM and dequantized after.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.quant_gemm import dequantize, quant_gemm, quantize

ACT_SCALE = 0.05
W_SCALE = 0.02

# Tiny-model dimensions (serving example / oracle artifacts).
HIDDEN = 64
FFN = 128
HEADS = 4
VOCAB = 256
SEQ = 16


def qmatmul(x_f32, w_q):
    """f32 activations × int8 weights through the Pallas int kernel."""
    x_q = quantize(x_f32, ACT_SCALE)
    acc = quant_gemm(x_q, w_q)
    return dequantize(acc, ACT_SCALE * W_SCALE)


def transformer_block(x, wqkv, wo, w1, w2):
    """One pre-norm transformer block.

    `x`: [S, H] f32; weights are int8-range int32:
    `wqkv`: [H, 3H], `wo`: [H, H], `w1`: [H, F], `w2`: [F, H].
    Returns [S, H] f32.
    """
    s, h = x.shape
    dh = h // HEADS

    def norm(v):
        mu = jnp.mean(v, axis=-1, keepdims=True)
        var = jnp.var(v, axis=-1, keepdims=True)
        return (v - mu) / jnp.sqrt(var + 1e-5)

    # Attention.
    qkv = qmatmul(norm(x), wqkv)  # [S, 3H]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(s, HEADS, dh).transpose(1, 0, 2)
    k = k.reshape(s, HEADS, dh).transpose(1, 0, 2)
    v = v.reshape(s, HEADS, dh).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", attn, v).transpose(1, 0, 2).reshape(s, h)
    x = x + dequantize(quant_gemm(quantize(ctx, ACT_SCALE), wo), ACT_SCALE * W_SCALE)

    # FFN.
    y = qmatmul(norm(x), w1)
    y = jax.nn.gelu(y)
    x = x + dequantize(quant_gemm(quantize(y, ACT_SCALE), w2), ACT_SCALE * W_SCALE)
    return x


def synthetic_weights(seed=0):
    """Deterministic int8-range weights for the tiny model."""
    rng = np.random.default_rng(seed)

    def w(shape):
        return jnp.asarray(
            rng.integers(-64, 64, size=shape, dtype=np.int32), dtype=jnp.int32
        )

    return {
        "wqkv": w((HIDDEN, 3 * HIDDEN)),
        "wo": w((HIDDEN, HIDDEN)),
        "w1": w((HIDDEN, FFN)),
        "w2": w((FFN, HIDDEN)),
        "w_vocab": w((HIDDEN, VOCAB)),
    }


def decode_step(x):
    """One recurrent decode step with weights baked as constants.

    `x`: [H] f32 hidden state → `[H + V]` f32: the next hidden state
    concatenated with the vocab logits (a single flat output keeps the
    Rust side's 1-tuple unwrapping simple).
    """
    w = synthetic_weights()
    h = transformer_block(x[None, :], w["wqkv"], w["wo"], w["w1"], w["w2"])[0]
    # Bounded, non-saturating recurrence: compress the block's dynamic
    # range before the tanh so small state perturbations (the token
    # feedback applied by the Rust coordinator) steer the trajectory.
    h = jnp.tanh(h * 0.05)
    logits = qmatmul(h[None, :], w["w_vocab"])[0]
    return jnp.concatenate([h, logits])
