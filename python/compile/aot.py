"""AOT pipeline: lower the L2/L1 computations once to HLO *text* under
`artifacts/` (run by `make artifacts`; a no-op afterwards thanks to the
Makefile stamp).

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that the runtime's xla_extension 0.5.1
rejects, while `HloModuleProto::from_text_file` reassigns ids cleanly (see
/opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.quant_gemm import quant_gemm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is essential: the default printer elides big
    # literals as `constant({...})`, which the text parser silently reads
    # back as ZEROS — baked weights would vanish.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constant survived printing"
    return text


# The fixed GEMM oracle shapes the Rust integration tests check the
# functional bit-serial executor against.
GEMM_ORACLES = [(16, 64, 8), (64, 256, 32)]


def artifacts(out_dir):
    """Yield (filename, hlo_text, meta) for every artifact."""
    for m, k, n in GEMM_ORACLES:
        spec_x = jax.ShapeDtypeStruct((m, k), jnp.int32)
        spec_w = jax.ShapeDtypeStruct((k, n), jnp.int32)
        lowered = jax.jit(quant_gemm).lower(spec_x, spec_w)
        yield (
            f"gemm_{m}x{k}x{n}.hlo.txt",
            to_hlo_text(lowered),
            {"kind": "gemm", "m": m, "k": k, "n": n, "dtype": "i32"},
        )

    x = jax.ShapeDtypeStruct((model.SEQ, model.HIDDEN), jnp.float32)
    wqkv = jax.ShapeDtypeStruct((model.HIDDEN, 3 * model.HIDDEN), jnp.int32)
    wo = jax.ShapeDtypeStruct((model.HIDDEN, model.HIDDEN), jnp.int32)
    w1 = jax.ShapeDtypeStruct((model.HIDDEN, model.FFN), jnp.int32)
    w2 = jax.ShapeDtypeStruct((model.FFN, model.HIDDEN), jnp.int32)
    lowered = jax.jit(model.transformer_block).lower(x, wqkv, wo, w1, w2)
    yield (
        "transformer_block.hlo.txt",
        to_hlo_text(lowered),
        {
            "kind": "transformer_block",
            "seq": model.SEQ,
            "hidden": model.HIDDEN,
            "ffn": model.FFN,
            "heads": model.HEADS,
        },
    )

    xs = jax.ShapeDtypeStruct((model.HIDDEN,), jnp.float32)
    lowered = jax.jit(model.decode_step).lower(xs)
    yield (
        "decode_step.hlo.txt",
        to_hlo_text(lowered),
        {"kind": "decode_step", "hidden": model.HIDDEN, "vocab": model.VOCAB},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, text, meta in artifacts(args.out_dir):
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
