"""L1 kernel correctness: the Pallas quantized GEMM against the pure-jnp
oracle, exactly (integer arithmetic), with hypothesis sweeping shapes,
values and block sizes."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quant_gemm import (
    BLOCK_K,
    BLOCK_M,
    BLOCK_N,
    dequantize,
    quant_gemm,
    quantize,
)
from compile.kernels.ref import dequantize_ref, quant_gemm_ref, quantize_ref

dims = st.integers(min_value=1, max_value=70)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def rand_int8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int32))


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=seeds)
def test_matches_reference_exactly(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (m, k))
    w = rand_int8(rng, (k, n))
    got = quant_gemm(x, w)
    want = quant_gemm_ref(x, w)
    assert got.shape == (m, n)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(seed=seeds, bm=st.sampled_from([4, 8, 16]), bk=st.sampled_from([8, 32]), bn=st.sampled_from([4, 16]))
def test_block_shape_invariance(seed, bm, bk, bn):
    """The result must not depend on the tiling (pure schedule change)."""
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (19, 45))
    w = rand_int8(rng, (45, 23))
    a = quant_gemm(x, w, bm=bm, bk=bk, bn=bn)
    b = quant_gemm_ref(x, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_extreme_values():
    x = jnp.full((8, 64), -128, dtype=jnp.int32)
    w = jnp.full((64, 8), 127, dtype=jnp.int32)
    got = quant_gemm(x, w)
    assert int(got[0, 0]) == -128 * 127 * 64


def test_zero_matrix():
    x = jnp.zeros((5, 7), dtype=jnp.int32)
    w = jnp.ones((7, 3), dtype=jnp.int32)
    assert not np.asarray(quant_gemm(x, w)).any()


def test_identity_weight():
    rng = np.random.default_rng(3)
    x = rand_int8(rng, (6, 6))
    eye = jnp.eye(6, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(quant_gemm(x, eye)), np.asarray(x))


def test_k_larger_than_block_accumulates():
    # K spans many grid steps; accumulation across revisits must be exact.
    k = BLOCK_K * 7 + 5
    rng = np.random.default_rng(11)
    x = rand_int8(rng, (BLOCK_M + 3, k))
    w = rand_int8(rng, (k, BLOCK_N + 1))
    np.testing.assert_array_equal(
        np.asarray(quant_gemm(x, w)), np.asarray(quant_gemm_ref(x, w))
    )


@settings(max_examples=20, deadline=None)
@given(seed=seeds, scale=st.floats(min_value=1e-3, max_value=1.0))
def test_quantize_roundtrip_bounds(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale * 40, size=(4, 4)).astype(np.float32))
    q = quantize(x, scale)
    q_ref = quantize_ref(x, scale)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    assert int(q.min()) >= -128 and int(q.max()) <= 127
    # Dequantized error bounded by half a quantization step (where not clipped).
    deq = dequantize(q, scale)
    unclipped = np.abs(np.asarray(x) / scale) <= 127
    err = np.abs(np.asarray(deq) - np.asarray(x))[unclipped]
    assert (err <= 0.5 * scale + 1e-6).all()
    np.testing.assert_array_equal(np.asarray(deq), np.asarray(dequantize_ref(q, scale)))


def test_rejects_mismatched_inner_dims():
    with pytest.raises(AssertionError):
        quant_gemm(jnp.zeros((2, 3), jnp.int32), jnp.zeros((4, 2), jnp.int32))
