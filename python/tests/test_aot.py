"""AOT pipeline checks: every artifact lowers to parseable HLO text with
the expected entry signature, and no artifact carries a Mosaic custom-call
(which the CPU PJRT client could not execute)."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def lowered():
    return {name: (text, meta) for name, text, meta in aot.artifacts("/tmp")}


def test_expected_artifact_set(lowered):
    names = set(lowered)
    assert "transformer_block.hlo.txt" in names
    assert "decode_step.hlo.txt" in names
    for m, k, n in aot.GEMM_ORACLES:
        assert f"gemm_{m}x{k}x{n}.hlo.txt" in names


def test_hlo_text_looks_like_hlo(lowered):
    for name, (text, _) in lowered.items():
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        assert len(text) > 500, name


def test_no_elided_constants(lowered):
    # The default HLO printer drops big literals as `{...}`, which the text
    # parser reads back as zeros — baked weights would silently vanish.
    for name, (text, _) in lowered.items():
        assert "constant({...})" not in text, name


def test_no_mosaic_custom_calls(lowered):
    # interpret=True must have lowered Pallas to plain HLO ops.
    for name, (text, _) in lowered.items():
        assert "tpu_custom_call" not in text, name
        assert "mosaic" not in text.lower(), name


def test_gemm_signature_is_int32(lowered):
    for m, k, n in aot.GEMM_ORACLES:
        text, meta = lowered[f"gemm_{m}x{k}x{n}.hlo.txt"]
        assert f"s32[{m},{k}]" in text
        assert f"s32[{k},{n}]" in text
        assert meta["dtype"] == "i32"


def test_decode_step_flat_output(lowered):
    text, meta = lowered["decode_step.hlo.txt"]
    flat = meta["hidden"] + meta["vocab"]
    assert f"f32[{flat}]" in text


def test_manifest_written_by_main(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest) == len(aot.GEMM_ORACLES) + 2
    for name in manifest:
        assert os.path.exists(tmp_path / name)
