"""L2 model checks: transformer block shapes/numerics and the decode step's
contract with the Rust coordinator."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.model import (
    HIDDEN,
    VOCAB,
    decode_step,
    synthetic_weights,
    transformer_block,
)


def x_input(seed=0, seq=model.SEQ):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 0.5, size=(seq, HIDDEN)).astype(np.float32))


def test_block_shape_and_finiteness():
    w = synthetic_weights()
    y = transformer_block(x_input(), w["wqkv"], w["wo"], w["w1"], w["w2"])
    assert y.shape == (model.SEQ, HIDDEN)
    assert bool(jnp.isfinite(y).all())


def test_block_is_deterministic():
    w = synthetic_weights()
    a = transformer_block(x_input(1), w["wqkv"], w["wo"], w["w1"], w["w2"])
    b = transformer_block(x_input(1), w["wqkv"], w["wo"], w["w1"], w["w2"])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_causal_masking():
    """Changing a later token must not affect earlier positions."""
    w = synthetic_weights()
    x1 = x_input(2)
    x2 = x1.at[-1].set(x1[-1] + 1.0)
    y1 = transformer_block(x1, w["wqkv"], w["wo"], w["w1"], w["w2"])
    y2 = transformer_block(x2, w["wqkv"], w["wo"], w["w1"], w["w2"])
    np.testing.assert_allclose(
        np.asarray(y1[:-1]), np.asarray(y2[:-1]), rtol=0, atol=0
    )
    assert not np.allclose(np.asarray(y1[-1]), np.asarray(y2[-1]))


def test_residual_path():
    """The block output stays in the same ballpark as its input (residual)."""
    w = synthetic_weights()
    x = x_input(3)
    y = transformer_block(x, w["wqkv"], w["wo"], w["w1"], w["w2"])
    assert float(jnp.abs(y).max()) < 1e3


def test_synthetic_weights_are_stable():
    a = synthetic_weights()
    b = synthetic_weights()
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
        assert int(a[k].min()) >= -128 and int(a[k].max()) <= 127


def test_decode_step_contract():
    """Output layout is [next_hidden(H); logits(V)] with bounded hidden."""
    x = jnp.asarray(np.linspace(-1, 1, HIDDEN).astype(np.float32))
    out = decode_step(x)
    assert out.shape == (HIDDEN + VOCAB,)
    hidden, logits = out[:HIDDEN], out[HIDDEN:]
    assert bool((jnp.abs(hidden) <= 1.0).all()), "tanh-bounded recurrence"
    assert bool(jnp.isfinite(logits).all())


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_decode_step_deterministic_and_sensitive(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 0.3, size=(HIDDEN,)).astype(np.float32))
    a = decode_step(x)
    b = decode_step(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # Different state → different logits (the engine can't be constant).
    c = decode_step(x + 0.1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_recurrence_converges_not_explodes():
    x = jnp.zeros((HIDDEN,), jnp.float32).at[0].set(1.0)
    for _ in range(20):
        out = decode_step(x)
        x = out[:HIDDEN]
    assert bool((jnp.abs(x) <= 1.0).all())
